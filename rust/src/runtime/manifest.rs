//! The artifact ABI: names, kinds, shapes — parsed from manifest.json.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One input's declared name and shape.
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl InputSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One compiled executable's metadata.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub arch: String,
    pub variant: String,
    pub rows: usize,
    pub block_rows: usize,
    pub s: usize,
    pub q: usize,
    pub m: usize,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
}

/// The full artifact registry.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    by_name: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = Json::parse(text)?;
        let mut by_name = BTreeMap::new();
        for e in root.get("artifacts")?.as_arr()? {
            let meta = ArtifactMeta {
                name: e.get("name")?.as_str()?.to_string(),
                file: e.get("file")?.as_str()?.to_string(),
                kind: e.get("kind")?.as_str()?.to_string(),
                arch: e.get("arch")?.as_str()?.to_string(),
                variant: e.get("variant")?.as_str()?.to_string(),
                rows: e.get("rows")?.as_usize()?,
                block_rows: e.get("block_rows")?.as_usize()?,
                s: e.get("s")?.as_usize()?,
                q: e.get("q")?.as_usize()?,
                m: e.get("m")?.as_usize()?,
                inputs: e
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(|i| {
                        Ok(InputSpec {
                            name: i.get("name")?.as_str()?.to_string(),
                            shape: i
                                .get("shape")?
                                .as_arr()?
                                .iter()
                                .map(|d| d.as_usize())
                                .collect::<Result<_>>()?,
                        })
                    })
                    .collect::<Result<_>>()?,
                outputs: e
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(|o| Ok(o.as_str()?.to_string()))
                    .collect::<Result<_>>()?,
            };
            if by_name.insert(meta.name.clone(), meta).is_some() {
                bail!("duplicate artifact name in manifest");
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), by_name })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.by_name
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    /// Find the unique artifact matching (kind, arch, q, m).
    pub fn find(&self, kind: &str, arch: &str, q: usize, m: usize) -> Result<&ArtifactMeta> {
        self.find_optional(kind, arch, q, m)?.ok_or_else(|| {
            anyhow!("no artifact for kind={kind} arch={arch} q={q} m={m} — extend python/compile/manifest.py")
        })
    }

    /// Like [`Manifest::find`], but absence is `Ok(None)` — for callers
    /// with a CPU fallback. Ambiguous manifests are still a hard error
    /// (that is a configuration bug, not a missing artifact).
    pub fn find_optional(
        &self,
        kind: &str,
        arch: &str,
        q: usize,
        m: usize,
    ) -> Result<Option<&ArtifactMeta>> {
        let mut hits = self
            .by_name
            .values()
            .filter(|a| a.kind == kind && a.arch == arch && a.q == q && a.m == m);
        let Some(first) = hits.next() else {
            return Ok(None);
        };
        if hits.next().is_some() {
            bail!("ambiguous artifact selection for kind={kind} arch={arch} q={q} m={m}");
        }
        Ok(Some(first))
    }

    pub fn all(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.by_name.values()
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {"name": "elm_gram_elman_r256_s1_q10_m50", "file": "elm_gram_elman_r256_s1_q10_m50.hlo.txt",
         "kind": "elm_gram", "arch": "elman", "variant": "opt",
         "rows": 256, "block_rows": 32, "s": 1, "q": 10, "m": 50,
         "inputs": [{"name": "x", "shape": [256, 1, 10], "dtype": "f32"},
                    {"name": "w", "shape": [1, 50], "dtype": "f32"}],
         "outputs": ["hth", "hty"]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let a = m.find("elm_gram", "elman", 10, 50).unwrap();
        assert_eq!(a.rows, 256);
        assert_eq!(a.inputs[0].name, "x");
        assert_eq!(a.inputs[0].len(), 2560);
        assert_eq!(a.outputs, vec!["hth", "hty"]);
    }

    #[test]
    fn missing_artifact_is_helpful() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let err = m.find("elm_gram", "elman", 99, 50).unwrap_err().to_string();
        assert!(err.contains("manifest.py"), "{err}");
    }

    #[test]
    fn real_manifest_loads_if_present() {
        let dir = crate::runtime::default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.all().count() >= 80, "expected the full grid");
            // every ELM artifact's first input is the x block
            for a in m.all().filter(|a| a.kind.starts_with("elm_")) {
                assert_eq!(a.inputs[0].name, "x");
                assert_eq!(a.inputs[0].shape, vec![a.rows, a.s, a.q]);
            }
        }
    }
}
