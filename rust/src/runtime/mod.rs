//! PJRT runtime: load AOT HLO artifacts and execute them from rust.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (the ABI emitted by
//!   `python/compile/aot.py`): per-artifact input names/shapes and outputs.
//! * [`engine`] — a single-threaded executor owning a `PjRtClient`
//!   (`Rc`-based in the xla crate, hence `!Send`): text-parse → compile →
//!   execute, with a compiled-executable cache.
//! * [`pool`] — `EnginePool`: N worker threads, each owning an `Engine`,
//!   fed over channels — the crate's thread-safe execution facade.
//!
//! HLO **text** is the interchange format (not serialized protos): jax ≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

#![forbid(unsafe_code)]

pub mod engine;
pub mod manifest;
pub mod pool;
#[cfg(not(feature = "pjrt"))]
pub(crate) mod xla_shim;

pub use engine::{Buf, Engine};
pub use manifest::{ArtifactMeta, Manifest};
pub use pool::EnginePool;

/// Default artifacts directory (relative to the repo root).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    // honor an override for tests / deployments
    if let Ok(d) = std::env::var("OPT_PR_ELM_ARTIFACTS") {
        return d.into();
    }
    // walk up from cwd until an artifacts/manifest.json is found
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
