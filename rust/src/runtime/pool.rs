//! `EnginePool`: thread-safe facade over N engine threads.
//!
//! The xla crate's `PjRtClient` is `Rc`-based (`!Send`), so each worker
//! thread owns its own client + executable cache; requests are dispatched
//! round-robin over channels. One worker is plenty for correctness paths;
//! benches can raise `workers` for inter-block parallelism.

#![forbid(unsafe_code)]

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;
// lint: allow(thread-confinement) -- handle type only; spawning is waived below
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use super::engine::{Buf, Engine, EngineStats};

enum Req {
    Run { name: String, inputs: Vec<Buf>, reply: Sender<Result<Vec<Buf>>> },
    Prepare { name: String, reply: Sender<Result<()>> },
    Stats { reply: Sender<EngineStats> },
    Shutdown,
}

struct Worker {
    tx: Mutex<Sender<Req>>,
    handle: Option<JoinHandle<()>>,
}

/// Thread-safe pool of PJRT engine threads.
pub struct EnginePool {
    workers: Vec<Worker>,
    next: AtomicUsize,
}

impl EnginePool {
    /// Spin up `n_workers` engine threads over `artifacts_dir`.
    pub fn new(artifacts_dir: &Path, n_workers: usize) -> Result<EnginePool> {
        let n = n_workers.max(1);
        let mut workers = Vec::with_capacity(n);
        for wid in 0..n {
            let (tx, rx) = channel::<Req>();
            let dir = artifacts_dir.to_path_buf();
            // engine construction happens on the worker thread (!Send);
            // surface construction errors through the first request instead
            // lint: allow(thread-confinement) -- PJRT artifact pool: long-lived engine owners off the deterministic solve path, not a compute fan-out
            let handle = std::thread::Builder::new()
                .name(format!("pjrt-engine-{wid}"))
                .spawn(move || {
                    let mut engine = Engine::new(&dir);
                    for req in rx {
                        match req {
                            Req::Run { name, inputs, reply } => {
                                let res = match &mut engine {
                                    Ok(e) => e.run(&name, &inputs),
                                    Err(e) => Err(anyhow!("engine init failed: {e:#}")),
                                };
                                let _ = reply.send(res);
                            }
                            Req::Prepare { name, reply } => {
                                let res = match &mut engine {
                                    Ok(e) => e.prepare(&name),
                                    Err(e) => Err(anyhow!("engine init failed: {e:#}")),
                                };
                                let _ = reply.send(res);
                            }
                            Req::Stats { reply } => {
                                let s = engine
                                    .as_ref()
                                    .map(|e| e.stats)
                                    .unwrap_or_default();
                                let _ = reply.send(s);
                            }
                            Req::Shutdown => break,
                        }
                    }
                })
                .context("spawning engine thread")?;
            workers.push(Worker { tx: Mutex::new(tx), handle: Some(handle) });
        }
        Ok(EnginePool { workers, next: AtomicUsize::new(0) })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    fn send(&self, wid: usize, req: Req) -> Result<()> {
        let tx = self.workers[wid].tx.lock().expect("pool poisoned");
        tx.send(req).map_err(|_| anyhow!("engine thread {wid} is gone"))
    }

    /// Execute on the next worker (round-robin).
    pub fn run(&self, name: &str, inputs: Vec<Buf>) -> Result<Vec<Buf>> {
        let wid = self.next.fetch_add(1, Ordering::Relaxed) % self.workers.len();
        self.run_on(wid, name, inputs)
    }

    /// Execute on a specific worker (cache affinity).
    pub fn run_on(&self, wid: usize, name: &str, inputs: Vec<Buf>) -> Result<Vec<Buf>> {
        let (reply, rx) = channel();
        self.send(wid, Req::Run { name: name.to_string(), inputs, reply })?;
        rx.recv().map_err(|_| anyhow!("engine thread {wid} dropped the reply"))?
    }

    /// Compile `name` on every worker (warm-up before timed runs).
    pub fn prepare_all(&self, name: &str) -> Result<()> {
        let mut rxs = Vec::new();
        for wid in 0..self.workers.len() {
            let (reply, rx) = channel();
            self.send(wid, Req::Prepare { name: name.to_string(), reply })?;
            rxs.push(rx);
        }
        for rx in rxs {
            rx.recv().map_err(|_| anyhow!("engine thread dropped prepare reply"))??;
        }
        Ok(())
    }

    /// Aggregate phase timings across workers (Fig 6 decomposition).
    pub fn stats(&self) -> EngineStats {
        let mut total = EngineStats::default();
        for wid in 0..self.workers.len() {
            let (reply, rx) = channel();
            if self.send(wid, Req::Stats { reply }).is_ok() {
                if let Ok(s) = rx.recv() {
                    total.compile_s += s.compile_s;
                    total.h2d_s += s.h2d_s;
                    total.exec_s += s.exec_s;
                    total.d2h_s += s.d2h_s;
                    total.executions += s.executions;
                }
            }
        }
        total
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        for w in &self.workers {
            if let Ok(tx) = w.tx.lock() {
                let _ = tx.send(Req::Shutdown);
            }
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}
