//! Offline stand-in for the PJRT-backed `xla` crate.
//!
//! The engine is written against the real crate's API (`PjRtClient`,
//! `Literal`, `HloModuleProto`, …). Deployment builds enable the `pjrt`
//! feature and link the real crate; the default (offline) build compiles
//! against this shim instead, so the whole runtime layer type-checks and
//! the rest of the crate — linalg, elm, coordinator CPU paths — is fully
//! usable without an XLA toolchain on the machine.
//!
//! Every construction entry point reports [`Error::unavailable`], and the
//! engine surfaces that as a readable "engine init failed" error through
//! [`super::pool::EnginePool`]; artifact-dependent tests skip themselves
//! when no manifest is present, so the shim never silently fakes results.

#![forbid(unsafe_code)]

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `anyhow` interop.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: PJRT runtime unavailable (offline build; \
                 enable the `pjrt` feature and link the xla crate)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Host literal: flat f32 payload + dims (the only dtype in our ABI).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error {
                msg: format!("cannot reshape {} elements to {dims:?}", self.data.len()),
            });
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (never constructible offline).
#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device-side buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// The client handle; `cpu()` is the engine's first call and the single
/// point where the offline build reports itself.
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_shape_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert!(l.reshape(&[7]).is_err());
    }
}
