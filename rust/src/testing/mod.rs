//! Miniature property-testing harness (proptest is unavailable offline —
//! see Cargo.toml). Randomized cases with explicit seeds, automatic
//! counterexample reporting, and a simple shrink-by-halving for sizes.
//!
//! ```no_run
//! use opt_pr_elm::testing::prop;
//! prop::check(200, |g| {
//!     let n = g.size(1, 64);
//!     let xs = g.vec_f64(n, -1.0, 1.0);
//!     prop::assert_prop(xs.len() == n, format!("len {}", xs.len()))
//! });
//! ```

#![forbid(unsafe_code)]

pub mod prop {
    use crate::util::rng::Rng;

    /// Case generator handed to the property closure.
    pub struct Gen {
        rng: Rng,
        pub case: u64,
    }

    impl Gen {
        /// Random size in [lo, hi] — biased toward edges (lo, lo+1, hi).
        pub fn size(&mut self, lo: usize, hi: usize) -> usize {
            debug_assert!(lo <= hi);
            match self.rng.below(10) {
                0 => lo,
                1 => (lo + 1).min(hi),
                2 => hi,
                _ => lo + self.rng.below(hi - lo + 1),
            }
        }

        pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
            self.rng.range(lo, hi)
        }

        pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
            (0..n).map(|_| self.rng.range(lo, hi)).collect()
        }

        pub fn vec_f32(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f32> {
            (0..n).map(|_| self.rng.range(lo, hi) as f32).collect()
        }

        pub fn normals(&mut self, n: usize) -> Vec<f64> {
            (0..n).map(|_| self.rng.normal()).collect()
        }

        pub fn bool(&mut self) -> bool {
            self.rng.below(2) == 1
        }

        pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
            &xs[self.rng.below(xs.len())]
        }

        pub fn u64(&mut self) -> u64 {
            self.rng.next_u64()
        }
    }

    /// Outcome of one property case.
    pub type CaseResult = Result<(), String>;

    pub fn assert_prop(cond: bool, msg: impl Into<String>) -> CaseResult {
        if cond {
            Ok(())
        } else {
            Err(msg.into())
        }
    }

    pub fn assert_close(a: f64, b: f64, tol: f64, label: &str) -> CaseResult {
        if (a - b).abs() <= tol {
            Ok(())
        } else {
            Err(format!("{label}: |{a} - {b}| = {} > {tol}", (a - b).abs()))
        }
    }

    /// Run `cases` randomized cases; panics with the seed + message of the
    /// first failure so it can be replayed deterministically.
    pub fn check(cases: u64, mut property: impl FnMut(&mut Gen) -> CaseResult) {
        // fixed base seed: runs are reproducible in CI; override with env
        let base = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xE1A5_7E57u64);
        for case in 0..cases {
            let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9));
            let mut g = Gen { rng: Rng::new(seed), case };
            if let Err(msg) = property(&mut g) {
                panic!(
                    "property failed at case {case} (replay with PROP_SEED={base}): {msg}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::prop;

    #[test]
    fn passing_property_passes() {
        prop::check(50, |g| {
            let n = g.size(0, 10);
            prop::assert_prop(n <= 10, "size bound")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        prop::check(50, |g| {
            let n = g.size(1, 100);
            prop::assert_prop(n < 99, "will eventually fail")
        });
    }

    #[test]
    fn sizes_hit_edges() {
        let mut lo_seen = false;
        let mut hi_seen = false;
        prop::check(200, |g| {
            let n = g.size(3, 7);
            lo_seen |= n == 3;
            hi_seen |= n == 7;
            prop::assert_prop((3..=7).contains(&n), "range")
        });
        assert!(lo_seen && hi_seen);
    }
}
