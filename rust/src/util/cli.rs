//! Tiny argv parser: `repro <command> [--key value] [--flag]`.
//!
//! Replaces clap in the offline build. Unknown options are an error so
//! typos fail loudly.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    known: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let v: Vec<String> = argv.into_iter().collect();
        let mut args = Args {
            command: v.first().cloned().unwrap_or_default(),
            ..Args::default()
        };
        let mut i = 1;
        while i < v.len() {
            let a = &v[i];
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare flag
                if let Some((k, val)) = key.split_once('=') {
                    args.options.insert(k.to_string(), val.to_string());
                } else if i + 1 < v.len() && !v[i + 1].starts_with("--") {
                    args.options.insert(key.to_string(), v[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn opt(&mut self, key: &str) -> Option<&str> {
        self.known.push(key.to_string());
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&mut self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&mut self, key: &str, default: usize) -> Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn opt_u64(&mut self, key: &str, default: u64) -> Result<u64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn flag(&mut self, key: &str) -> bool {
        self.known.push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Call after all opt()/flag() lookups: rejects unknown options.
    pub fn finish(&self) -> Result<()> {
        for k in self.options.keys() {
            if !self.known.contains(k) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !self.known.contains(f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_command_and_options() {
        let mut a = parse(&["report", "table4", "--seed", "7", "--arch=lstm"]);
        assert_eq!(a.command, "report");
        assert_eq!(a.positional, vec!["table4"]);
        assert_eq!(a.opt("seed"), Some("7"));
        assert_eq!(a.opt("arch"), Some("lstm"));
        a.finish().unwrap();
    }

    #[test]
    fn flags_and_lookahead() {
        let mut a = parse(&["train", "--verbose", "--m", "50"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_usize("m", 10).unwrap(), 50);
        a.finish().unwrap();
    }

    #[test]
    fn adjacent_flags() {
        let mut a = parse(&["x", "--fast", "--check"]);
        assert!(a.flag("fast"));
        assert!(a.flag("check"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_option_rejected() {
        let mut a = parse(&["x", "--oops", "1"]);
        let _ = a.opt("other");
        assert!(a.finish().is_err());
    }

    #[test]
    fn defaults() {
        let mut a = parse(&["x"]);
        assert_eq!(a.opt_or("mode", "fast"), "fast");
        assert_eq!(a.opt_usize("n", 3).unwrap(), 3);
    }
}
