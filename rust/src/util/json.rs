//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Only what `artifacts/manifest.json` and the results store need — objects,
//! arrays, strings (with escapes), numbers, booleans, null. No serde in the
//! offline build, so this is a first-class substrate with its own tests.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    // -- writer ----------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    x.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    x.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at offset {}", c as char, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at offset {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at offset {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("bad escape \\{:?}", c as char),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence starting at c
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn escapes_round_trip() {
        let orig = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let text = orig.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), orig);
    }

    #[test]
    fn unicode_round_trip() {
        let j = Json::parse("\"héllo \\u00e9 ≈\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo é ≈");
    }

    #[test]
    fn pretty_round_trip() {
        let j = Json::parse(r#"{"x": [1.5, true, "s"], "y": {"z": []}}"#).unwrap();
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123abc").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn integer_output_has_no_fraction() {
        assert_eq!(Json::Num(256.0).to_string_pretty(), "256");
    }

    #[test]
    fn typed_accessor_errors() {
        let j = Json::parse(r#"{"n": 1.5}"#).unwrap();
        assert!(j.get("n").unwrap().as_usize().is_err());
        assert!(j.get("missing").is_err());
        assert!(j.get("n").unwrap().as_str().is_err());
    }
}
