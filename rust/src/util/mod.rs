//! Small self-contained substrates: RNG, JSON, CLI parsing, tables, timing.
//!
//! These replace crates that are unavailable in the offline build
//! (rand, serde_json, clap, criterion) — see the note in `Cargo.toml`.

#![forbid(unsafe_code)]

pub mod cli;
pub mod json;
pub mod rng;
pub mod table;
pub mod timer;
