//! Deterministic, seedable PRNG: SplitMix64 core with uniform / normal
//! helpers. Used for ELM random weights, synthetic datasets, and property
//! tests — reproducibility across runs is a §7.3 (robustness) requirement,
//! so everything that draws randomness takes an explicit seed.

#![forbid(unsafe_code)]

/// SplitMix64: tiny, fast, passes BigCrush when used as a stream.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// cached second Box-Muller output
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), spare: None }
    }

    /// Derive an independent stream (e.g. per worker / per matrix).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        Rng::new(s)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// ELM random-weight regime: uniform [-1, 1] f32 buffer.
    pub fn weights(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.range(-1.0, 1.0) as f32).collect()
    }

    /// Standard-normal f32 buffer.
    pub fn normals_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weights_in_elm_range() {
        let mut r = Rng::new(9);
        for w in r.weights(1000) {
            assert!((-1.0..=1.0).contains(&w));
        }
    }
}
