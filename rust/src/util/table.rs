//! Markdown table formatting for the report emitters (Tables 2-6, Figs 3-6
//! as series tables). Columns are auto-width; numbers are right-aligned.

#![forbid(unsafe_code)]

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{}-|", "-".repeat(w + 1)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Compact scientific formatting like the paper's tables: `3.97E-2`.
pub fn sci(v: f64) -> String {
    if v == 0.0 {
        return "0.00E+0".to_string();
    }
    let exp = v.abs().log10().floor() as i32;
    let mant = v / 10f64.powi(exp);
    format!("{:.2}E{}{}", mant, if exp < 0 { "-" } else { "+" }, exp.abs())
}

/// Fixed-precision seconds.
pub fn secs(v: f64) -> String {
    if v < 0.01 {
        format!("{:.2}ms", v * 1e3)
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 3);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a"]);
        t.row(vec!["x,y\"z".into()]);
        assert_eq!(t.to_csv(), "a\n\"x,y\"\"z\"\n");
    }

    #[test]
    fn sci_matches_paper_style() {
        assert_eq!(sci(0.0397), "3.97E-2");
        assert_eq!(sci(1113.0), "1.11E+3");
        assert_eq!(sci(0.0), "0.00E+0");
    }
}
