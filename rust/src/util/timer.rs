//! Wall-clock timing + a tiny bench harness (criterion replacement).
//!
//! `bench()` runs warmup iterations, then measures until a time budget or
//! iteration cap is reached, and reports mean / p50 / p95 like a criterion
//! summary line. Used by every `rust/benches/*.rs` (harness = false).

#![forbid(unsafe_code)]

use std::time::{Duration, Instant};

/// Measure one closure invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  ({} iters)",
            self.name, self.mean, self.p50, self.p95, self.iters
        )
    }
}

/// Criterion-style measurement loop: `warmup` unmeasured runs, then measure
/// until `budget` elapses (at least 3, at most `max_iters` runs).
pub fn bench<T>(
    name: &str,
    warmup: usize,
    budget: Duration,
    max_iters: usize,
    mut f: impl FnMut() -> T,
) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while (samples.len() < 3 || start.elapsed() < budget) && samples.len() < max_iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let mean = total / samples.len() as u32;
    let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    BenchResult { name: name.to_string(), iters: samples.len(), mean, p50: p(0.5), p95: p(0.95) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_at_least_three() {
        let r = bench("noop", 1, Duration::from_millis(1), 1000, || 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.p50 <= r.p95);
    }

    #[test]
    fn bench_respects_max_iters() {
        let r = bench("capped", 0, Duration::from_secs(10), 5, || ());
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}
