//! Property tests over the blocked substrate introduced by the panel-QR /
//! tiled-GEMM / parallel-TSQR rework: every fast path is pinned to its
//! scalar reference oracle.

use opt_pr_elm::coordinator::pipeline::CpuElmTrainer;
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::elm::arch::{h_block, h_row, SampleBlock};
use opt_pr_elm::elm::{Arch, ElmParams, ALL_ARCHS};
use opt_pr_elm::linalg::{
    householder_qr, householder_qr_reference, lstsq_qr, lstsq_tsqr, Matrix,
    ParallelPolicy, TsqrAccumulator,
};
use opt_pr_elm::testing::prop;
use opt_pr_elm::util::rng::Rng;

fn random_matrix(g: &mut prop::Gen, rows: usize, cols: usize) -> Matrix {
    let mut rng = Rng::new(g.u64());
    Matrix::random(rows, cols, &mut rng)
}

#[test]
fn blocked_qr_matches_reference_property() {
    // tall and square, spanning one to several panels
    prop::check(30, |g| {
        let n = 1 + g.size(0, 80);
        let m = n + g.size(0, 120);
        let a = random_matrix(g, m, n);
        let blocked = householder_qr(&a).map_err(|e| e.to_string())?;
        let reference = householder_qr_reference(&a).map_err(|e| e.to_string())?;
        let dr = blocked.r().max_abs_diff(&reference.r());
        prop::assert_close(dr, 0.0, 1e-10, &format!("R blocked vs ref {m}x{n}"))?;
        // Qᵀb must agree as well (the factors, not just R)
        let b = g.normals(m);
        let mut qb = b.clone();
        let mut qr = b;
        blocked.apply_qt(&mut qb);
        reference.apply_qt(&mut qr);
        let worst = qb
            .iter()
            .zip(&qr)
            .take(n)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        prop::assert_close(worst, 0.0, 1e-9, "Qᵀb blocked vs ref")
    });
}

#[test]
fn blocked_qr_rank_deficient_property() {
    // duplicated / zero columns: both paths must still produce a valid
    // factorization (A = QR to 1e-10); R entries in noise directions are
    // implementation-defined, so the oracle here is reconstruction
    prop::check(20, |g| {
        let base_n = 1 + g.size(0, 20);
        let m = base_n * 2 + 8 + g.size(0, 60);
        let base = random_matrix(g, m, base_n);
        let n = base_n * 2;
        let mut a = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..base_n {
                a[(i, j)] = base[(i, j)];
                a[(i, base_n + j)] = if g.case % 3 == 0 { 0.0 } else { base[(i, j)] };
            }
        }
        for f in [householder_qr(&a), householder_qr_reference(&a)] {
            let f = f.map_err(|e| e.to_string())?;
            let qr = f.q().matmul(&f.r());
            prop::assert_close(
                qr.max_abs_diff(&a),
                0.0,
                1e-10,
                &format!("rank-deficient A=QR {m}x{n}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn tiled_matmul_matches_naive_property() {
    prop::check(40, |g| {
        let m = 1 + g.size(0, 90);
        let k = 1 + g.size(0, 90);
        let n = 1 + g.size(0, 90);
        let a = random_matrix(g, m, k);
        let b = random_matrix(g, k, n);
        let tiled = a.matmul(&b);
        // unblocked ijk oracle
        let mut naive = Matrix::zeros(m, n);
        for i in 0..m {
            for kk in 0..k {
                let v = a[(i, kk)];
                for j in 0..n {
                    naive[(i, j)] += v * b[(kk, j)];
                }
            }
        }
        prop::assert_prop(tiled == naive, format!("matmul {m}x{k}x{n} not bit-equal"))
    });
}

#[test]
fn h_block_matches_h_row_property() {
    prop::check(25, |g| {
        let s = 1 + g.size(0, 2);
        let q = 1 + g.size(0, 9);
        let m = 1 + g.size(0, 11);
        let rows = 1 + g.size(0, 40);
        let x = g.vec_f32(rows * s * q, -1.0, 1.0);
        let yh = g.vec_f32(rows * q, -0.5, 0.5);
        let eh = g.vec_f32(rows * q, -0.5, 0.5);
        for arch in ALL_ARCHS {
            let p = ElmParams::init(arch, s, q, m, g.u64());
            let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };
            let hb = h_block(&p, &blk);
            let mut out = vec![0f32; m];
            for i in 0..rows {
                h_row(
                    &p,
                    &x[i * s * q..(i + 1) * s * q],
                    &yh[i * q..(i + 1) * q],
                    &eh[i * q..(i + 1) * q],
                    &mut out,
                );
                for j in 0..m {
                    prop::assert_close(
                        hb[(i, j)],
                        out[j] as f64,
                        1e-5,
                        &format!("{arch:?} ({s},{q},{m}) row {i} col {j}"),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn parallel_tsqr_tree_bit_identical_property() {
    // the §7.3 requirement: identical bits at 1/2/4/8 workers
    prop::check(12, |g| {
        let n = 1 + g.size(0, 7);
        let rows = n + 8 + g.size(0, 400);
        let a = random_matrix(g, rows, n);
        let b = g.normals(rows);
        let block = 1 + g.size(0, 60);
        let mut blocks = Vec::new();
        let mut i = 0;
        while i < rows {
            let hi = (i + block).min(rows);
            blocks.push((a.submatrix(i, hi, 0, n), b[i..hi].to_vec()));
            i = hi;
        }
        let base = TsqrAccumulator::reduce(n, blocks.clone(), ParallelPolicy::sequential())
            .map_err(|e| e.to_string())?;
        for workers in [2usize, 4, 8] {
            let acc = TsqrAccumulator::reduce(
                n,
                blocks.clone(),
                ParallelPolicy::with_workers(workers),
            )
            .map_err(|e| e.to_string())?;
            prop::assert_prop(
                acc.r_factor() == base.r_factor()
                    && acc.z_factor() == base.z_factor(),
                format!("tree differs at workers={workers} (block={block})"),
            )?;
        }
        // and the tree must solve the same least-squares problem
        let direct = lstsq_qr(&a, &b).map_err(|e| e.to_string())?;
        let tree = base.solve().map_err(|e| e.to_string())?;
        let worst = tree
            .iter()
            .zip(&direct)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        prop::assert_close(worst, 0.0, 1e-7, "tree vs direct β")
    });
}

#[test]
fn lstsq_tsqr_worker_invariance_property() {
    prop::check(15, |g| {
        let n = 1 + g.size(0, 6);
        let rows = n + 4 + g.size(0, 900);
        let a = random_matrix(g, rows, n);
        let b = g.normals(rows);
        let base =
            lstsq_tsqr(&a, &b, ParallelPolicy::sequential()).map_err(|e| e.to_string())?;
        for workers in [2usize, 5, 8] {
            let beta = lstsq_tsqr(&a, &b, ParallelPolicy::with_workers(workers))
                .map_err(|e| e.to_string())?;
            prop::assert_prop(
                beta == base,
                format!("lstsq_tsqr bits differ at workers={workers}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn cpu_pipeline_worker_invariance() {
    // end to end: batched H + parallel reduction, bit-identical β
    let mut rng = Rng::new(17);
    let series: Vec<f64> = {
        let mut y = vec![0.4f64, 0.5];
        for t in 2..420 {
            let v = 0.5 * y[t - 1] + 0.2 * y[t - 2] + 0.1 * (t as f64 * 0.19).sin()
                + 0.05 * rng.normal();
            y.push(v.clamp(-2.0, 2.0));
        }
        y
    };
    let w = Windowed::from_series(&series, 6).unwrap();
    for archk in [Arch::Elman, Arch::Lstm, Arch::Narmax] {
        let mut base: Option<Vec<f64>> = None;
        for workers in [1usize, 2, 4, 8] {
            let mut t = CpuElmTrainer::new(workers);
            t.block_rows = 48;
            let (model, _) = t.train(archk, &w, 8, 11).unwrap();
            match &base {
                None => base = Some(model.beta),
                Some(b) => assert_eq!(b, &model.beta, "{archk:?} workers={workers}"),
            }
        }
    }
}
