//! P-BPTT driver integration: the AOT train step must actually learn, and
//! the loss log must be the Fig-5-shaped decreasing curve.

// Every test below is `#[ignore]`d by default: it needs the real PJRT
// runtime (`pjrt` feature + AOT artifacts from python/compile), which the
// offline build replaces with the erroring xla shim. The in-test
// `artifacts_ready()` guard is kept so `--ignored` runs still self-skip
// gracefully when artifacts are missing. Tracking: ISSUE 2 satellite
// "triage the failing seed tests".
use opt_pr_elm::bptt::{BpttArch, BpttTrainer};
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::runtime::default_artifacts_dir;
use opt_pr_elm::util::rng::Rng;

fn artifacts_ready() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

fn toy_series(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut y = vec![0.2f64, 0.4];
    for t in 2..n {
        let v = 0.55 * y[t - 1] + 0.25 * y[t - 2]
            + 0.1 * (t as f64 * 0.2).sin()
            + 0.03 * rng.normal();
        y.push(v);
    }
    let lo = y.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = y.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    y.iter().map(|v| (v - lo) / (hi - lo)).collect()
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn bptt_learns_all_three_archs() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let trainer = BpttTrainer::new(&default_artifacts_dir()).unwrap();
    let series = toy_series(1400, 3);
    let w = Windowed::from_series(&series, 10).unwrap();
    let (train, test) = w.split(0.8);

    for arch in [BpttArch::Fc, BpttArch::Lstm, BpttArch::Gru] {
        let (model, log) = trainer.train(arch, &train, 10, 7).unwrap();
        assert_eq!(log.epochs, 10);
        assert!(log.steps >= 10);
        let first: f64 =
            log.points.iter().take(3).map(|p| p.mse).sum::<f64>() / 3.0;
        let last: f64 = log
            .points
            .iter()
            .rev()
            .take(3)
            .map(|p| p.mse)
            .sum::<f64>()
            / 3.0;
        assert!(
            last < 0.5 * first,
            "{}: loss {first} -> {last} did not halve",
            arch.name()
        );
        // timestamps are monotone and positive
        for w in log.points.windows(2) {
            assert!(w[1].t_s >= w[0].t_s);
        }
        let test_mse = trainer.mse(&model, &test).unwrap();
        assert!(test_mse.is_finite() && test_mse < first, "{}", arch.name());
        println!(
            "{:>4}: mse {first:.4} -> {last:.4}, test {test_mse:.4}, {:.2}s / {} steps",
            arch.name(),
            log.total_s,
            log.steps
        );
    }
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn bptt_deterministic_in_seed() {
    if !artifacts_ready() {
        return;
    }
    let trainer = BpttTrainer::new(&default_artifacts_dir()).unwrap();
    let series = toy_series(400, 5);
    let w = Windowed::from_series(&series, 10).unwrap();
    let (a, _) = trainer.train(BpttArch::Gru, &w, 10, 42).unwrap();
    let (b, _) = trainer.train(BpttArch::Gru, &w, 10, 42).unwrap();
    assert_eq!(a.params, b.params);
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn bptt_rejects_tiny_dataset() {
    if !artifacts_ready() {
        return;
    }
    let trainer = BpttTrainer::new(&default_artifacts_dir()).unwrap();
    let series = toy_series(40, 1); // 30 windows < batch 64
    let w = Windowed::from_series(&series, 10).unwrap();
    assert!(trainer.train(BpttArch::Fc, &w, 10, 1).is_err());
}
