//! Property tests over the coordinator substrate (no PJRT needed):
//! batching coverage, mask correctness, accumulator algebra.

use opt_pr_elm::coordinator::batcher::RowBlockBatcher;
use opt_pr_elm::coordinator::GramAccumulator;
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::testing::prop;

fn toy_windowed(g: &mut prop::Gen, q: usize, n_rows: usize) -> Windowed {
    let series = g.vec_f64(n_rows + q, -1.0, 1.0);
    Windowed::from_series(&series, q).unwrap()
}

#[test]
fn batcher_tiles_exactly_property() {
    prop::check(80, |g| {
        let q = g.size(1, 8);
        let n = 1 + g.size(0, 700);
        let rows = 1 + g.size(0, 300);
        let w = toy_windowed(g, q, n);
        let blocks: Vec<_> = RowBlockBatcher::new(&w, rows).collect();
        let total: usize = blocks.iter().map(|b| b.valid).sum();
        prop::assert_prop(total == w.n, format!("covered {total} of {}", w.n))?;
        // offsets are contiguous, block shapes fixed
        let mut pos = 0;
        for b in &blocks {
            prop::assert_prop(b.offset == pos, "contiguous offsets")?;
            prop::assert_prop(b.x.len() == rows * w.s * w.q, "x padded shape")?;
            prop::assert_prop(b.mask.len() == rows, "mask shape")?;
            let mask_sum: f32 = b.mask.iter().sum();
            prop::assert_prop(mask_sum as usize == b.valid, "mask sums to valid")?;
            pos += b.valid;
        }
        // every block except possibly the last is full
        for b in &blocks[..blocks.len().saturating_sub(1)] {
            prop::assert_prop(b.valid == rows, "interior blocks full")?;
        }
        Ok(())
    });
}

#[test]
fn batcher_padding_is_zero_property() {
    prop::check(50, |g| {
        let q = g.size(1, 6);
        let n = 1 + g.size(0, 150);
        let rows = n + 1 + g.size(0, 64); // force padding
        let w = toy_windowed(g, q, n);
        let blocks: Vec<_> = RowBlockBatcher::new(&w, rows).collect();
        prop::assert_prop(blocks.len() == 1, "single padded block")?;
        let b = &blocks[0];
        let pad_x = &b.x[b.valid * w.s * w.q..];
        let pad_y = &b.y[b.valid..];
        prop::assert_prop(pad_x.iter().all(|&v| v == 0.0), "x padding zero")?;
        prop::assert_prop(pad_y.iter().all(|&v| v == 0.0), "y padding zero")?;
        prop::assert_prop(
            b.mask[b.valid..].iter().all(|&v| v == 0.0),
            "mask padding zero",
        )
    });
}

#[test]
fn gram_accumulation_is_order_invariant_property() {
    // folding partials in any order gives the same solution (f64 fold of
    // identical summands — merge() is commutative here)
    prop::check(30, |g| {
        let m = 2 + g.size(0, 6);
        let n_blocks = 2 + g.size(0, 6);
        // random per-block partials (symmetric PSD-ish: outer products)
        let mut partials = Vec::new();
        for _ in 0..n_blocks {
            let v = g.vec_f32(m, -1.0, 1.0);
            let mut hth = vec![0f32; m * m];
            let mut hty = vec![0f32; m];
            for a in 0..m {
                for b in 0..m {
                    hth[a * m + b] = v[a] * v[b] + if a == b { 1.0 } else { 0.0 };
                }
                hty[a] = v[a] * 0.5;
            }
            partials.push((hth, hty));
        }
        let solve_in_order = |idx: Vec<usize>| -> Result<Vec<f64>, String> {
            let mut acc = GramAccumulator::new(m, 1e-10);
            for &i in &idx {
                acc.push_partials(&partials[i].0, &partials[i].1, m)
                    .map_err(|e| e.to_string())?;
            }
            acc.solve().map_err(|e| e.to_string())
        };
        let fwd = solve_in_order((0..n_blocks).collect())?;
        let rev = solve_in_order((0..n_blocks).rev().collect())?;
        let worst = fwd
            .iter()
            .zip(&rev)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        prop::assert_close(worst, 0.0, 1e-9, "order invariance")
    });
}

#[test]
fn merge_matches_sequential_property() {
    prop::check(30, |g| {
        let m = 2 + g.size(0, 5);
        let k = 2 + g.size(0, 5);
        let mut seq = GramAccumulator::new(m, 1e-10);
        let mut left = GramAccumulator::new(m, 1e-10);
        let mut right = GramAccumulator::new(m, 1e-10);
        for i in 0..k {
            let v = g.vec_f32(m, -1.0, 1.0);
            let mut hth = vec![0f32; m * m];
            let mut hty = vec![0f32; m];
            for a in 0..m {
                for b in 0..m {
                    hth[a * m + b] = v[a] * v[b] + if a == b { 0.7 } else { 0.0 };
                }
                hty[a] = v[a];
            }
            seq.push_partials(&hth, &hty, m).map_err(|e| e.to_string())?;
            if i % 2 == 0 {
                left.push_partials(&hth, &hty, m).map_err(|e| e.to_string())?;
            } else {
                right.push_partials(&hth, &hty, m).map_err(|e| e.to_string())?;
            }
        }
        left.merge(&right).map_err(|e| e.to_string())?;
        prop::assert_prop(left.rows_seen() == seq.rows_seen(), "rows merged")?;
        let a = seq.solve().map_err(|e| e.to_string())?;
        let b = left.solve().map_err(|e| e.to_string())?;
        let worst = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        prop::assert_close(worst, 0.0, 1e-8, "merge == sequential")
    });
}
