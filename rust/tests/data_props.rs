//! Property tests over the data substrate: windowing alignment, splits,
//! normalization, and generator statistics.

use opt_pr_elm::data::spec::registry;
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::data::{MinMax, Stats};
use opt_pr_elm::testing::prop;

#[test]
fn window_alignment_property() {
    prop::check(60, |g| {
        let q = g.size(1, 20);
        let n = q + 1 + g.size(0, 200);
        let series = g.vec_f64(n, -5.0, 5.0);
        let w = Windowed::from_series(&series, q).map_err(|e| e.to_string())?;
        prop::assert_prop(w.n == n - q, "window count")?;
        // spot-check a random row
        let i = g.size(0, w.n - 1);
        for t in 0..q {
            prop::assert_close(
                w.x_row(i)[t] as f64,
                series[i + t],
                1e-6,
                &format!("x[{i},{t}]"),
            )?;
        }
        prop::assert_close(w.y[i] as f64, series[i + q], 1e-6, "target")?;
        // yhist is the reversed window
        for k in 1..=q {
            prop::assert_close(
                w.yhist_row(i)[k - 1] as f64,
                series[i + q - k],
                1e-6,
                "yhist",
            )?;
        }
        Ok(())
    });
}

#[test]
fn split_partition_property() {
    prop::check(60, |g| {
        let q = g.size(1, 8);
        let n = q + 2 + g.size(0, 300);
        let series = g.vec_f64(n, 0.0, 1.0);
        let w = Windowed::from_series(&series, q).map_err(|e| e.to_string())?;
        let frac = g.f64(0.0, 1.0);
        let (tr, te) = w.split(frac);
        prop::assert_prop(tr.n + te.n == w.n, "partition covers")?;
        prop::assert_prop(tr.n >= 1 && te.n >= 1, "both nonempty")?;
        // boundary continuity: first test row is the (tr.n)-th source row
        prop::assert_close(te.y[0] as f64, w.y[tr.n] as f64, 0.0, "boundary")
    });
}

#[test]
fn minmax_normalization_property() {
    prop::check(60, |g| {
        let n = g.size(2, 500);
        let xs = g.vec_f64(n, -1e6, 1e6);
        let nm = MinMax::fit(&xs).map_err(|e| e.to_string())?;
        let z = nm.apply_all(&xs);
        let s = Stats::of(&z);
        prop::assert_prop(s.min() >= -1e-9 && s.max() <= 1.0 + 1e-9, "unit range")?;
        // round trip
        let i = g.size(0, n - 1);
        prop::assert_close(nm.invert(z[i]), xs[i], 1e-6 * (1.0 + xs[i].abs()), "invert")
    });
}

#[test]
fn generators_respect_bounds_property() {
    // every dataset, several scales/seeds: published min/max are hard bounds
    prop::check(20, |g| {
        let specs = registry();
        let d = g.pick(&specs);
        let scale = g.f64(0.01, 0.05);
        let seed = g.u64();
        let xs = d.generate(scale, seed);
        let s = Stats::of(&xs);
        prop::assert_prop(
            s.min() >= d.min - 1e-9 && s.max() <= d.max + 1e-9,
            format!("{}: [{}, {}] outside published bounds", d.name, s.min(), s.max()),
        )?;
        prop::assert_prop(xs.iter().all(|v| v.is_finite()), "finite")
    });
}

#[test]
fn window_slice_composition_property() {
    prop::check(40, |g| {
        let q = g.size(1, 6);
        let n = q + 10 + g.size(0, 100);
        let series = g.vec_f64(n, -2.0, 2.0);
        let w = Windowed::from_series(&series, q).map_err(|e| e.to_string())?;
        let lo = g.size(0, w.n - 2);
        let hi = lo + 1 + g.size(0, w.n - lo - 1);
        let s = w.slice(lo, hi);
        prop::assert_prop(s.n == hi - lo, "slice len")?;
        let i = g.size(0, s.n - 1);
        prop::assert_prop(s.x_row(i) == w.x_row(lo + i), "slice x rows")?;
        prop::assert_prop(s.y[i] == w.y[lo + i], "slice y")
    });
}
