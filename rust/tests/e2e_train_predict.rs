//! Architecture-sweep conformance suite: end-to-end train + predict for
//! all six architectures on a `data::synth` series, pinning
//!
//! * (a) **determinism** — β from `CpuElmTrainer` is bit-identical at
//!   1/2/4/8 workers, and (for the five QR-solved architectures) bit-
//!   identical to the sequential `lstsq_qr` on the same H. NARMAX never
//!   takes the QR path even sequentially (two-pass ELS with its ridge
//!   floor, see `TrainOptions::NARMAX_RIDGE`), so for it the anchor is
//!   worker-count invariance of the ridge pipeline alone.
//! * (b) **accuracy** — test-set MSE is finite and below a per-arch
//!   ceiling (and below the mean-predictor baseline).
//!
//! This is the suite that makes the threaded substrate safe to keep
//! rewriting: any reassociation snuck into a "fast path" shows up here as
//! a bit mismatch.
//!
//! The sweep also covers the **f32-wire** trainer (`Precision::MixedF32`):
//! H blocks are f32-born at the arch kernels and stay f32 to the Gram
//! kernels (`gram_widen`/`t_matvec_widen`) or the TSQR leaves
//! (`reduce_f32`, exact widen at the leaf QR). β must be bit-identical at
//! 1/2/4/8 workers on every strategy, the QR-strategy β must reproduce
//! the sequential `lstsq_qr` bits (the f32 wire is an exact re-encoding
//! of H), and the trained models must clear the same per-arch MSE
//! ceilings as the f64 path.

use opt_pr_elm::coordinator::accumulator::SolveStrategy;
use opt_pr_elm::coordinator::CpuElmTrainer;
use opt_pr_elm::data::synth;
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::data::MinMax;
use opt_pr_elm::elm::trainer::hidden_matrix;
use opt_pr_elm::elm::{Arch, ElmParams, ALL_ARCHS};
use opt_pr_elm::linalg::{lstsq_qr, ParallelPolicy, Precision, RecurrenceMode};
use opt_pr_elm::util::rng::Rng;

const M: usize = 12;
const SEED: u64 = 5;
const Q: usize = 8;

/// AEMO electricity load (strong half-hourly daily cycle): predictable
/// one-step-ahead, so every architecture should model it comfortably.
fn prepared() -> (Windowed, Windowed) {
    let mut rng = Rng::new(11);
    let series = synth::aemo(1200, &mut rng);
    let split_at = (series.len() as f64 * 0.8) as usize;
    let norm = MinMax::fit(&series[..split_at]).unwrap();
    let z = norm.apply_all(&series);
    let w = Windowed::from_series(&z, Q).unwrap();
    w.split(0.8)
}

fn trainer(workers: usize) -> CpuElmTrainer {
    let mut t = CpuElmTrainer::new(workers);
    t.strategy = SolveStrategy::DirectQr;
    t.block_rows = 64; // several blocks per worker at this n
    t
}

/// Per-arch MSE ceilings on the normalized [0, 1] scale: loose sanity
/// bounds (the strict claim is beating the mean predictor), NARMAX looser
/// because its error-feedback loop adds prediction-time noise. One
/// definition shared by the f64 and f32-wire sweeps so both enforce the
/// same quality bar.
fn ceiling(arch: Arch) -> f64 {
    match arch {
        Arch::Narmax => 0.10,
        _ => 0.06,
    }
}

#[test]
fn beta_bit_identical_across_worker_counts_all_archs() {
    let (train, _test) = prepared();
    for arch in ALL_ARCHS {
        let mut base: Option<Vec<f64>> = None;
        for workers in [1usize, 2, 4, 8] {
            let (model, bd) = trainer(workers).train(arch, &train, M, SEED).unwrap();
            assert!(bd.blocks > 0);
            match &base {
                None => base = Some(model.beta),
                Some(b) => assert_eq!(
                    b,
                    &model.beta,
                    "{}: β bits differ at workers={workers}",
                    arch.name()
                ),
            }
        }
    }
}

#[test]
fn beta_bit_identical_to_sequential_lstsq_qr() {
    // the five QR-solved architectures must reproduce the sequential
    // lstsq_qr bits exactly, whatever the worker count — the H blocks are
    // sample-independent and the threaded QR's splits are fixed schedules
    let (train, _test) = prepared();
    let y: Vec<f64> = train.y.iter().map(|&v| v as f64).collect();
    for arch in [Arch::Fc, Arch::Elman, Arch::Jordan, Arch::Lstm, Arch::Gru] {
        let params = ElmParams::init(arch, train.s, train.q, M, SEED);
        let h = hidden_matrix(&params, &train, None);
        let seq = lstsq_qr(&h, &y).unwrap();
        for workers in [1usize, 2, 4, 8] {
            let (model, _) = trainer(workers).train(arch, &train, M, SEED).unwrap();
            assert_eq!(
                model.beta,
                seq,
                "{}: parallel β != sequential lstsq_qr at workers={workers}",
                arch.name()
            );
        }
    }
}

#[test]
fn test_mse_finite_and_below_ceiling_all_archs() {
    let (train, test) = prepared();
    let ymean = test.y.iter().map(|&v| v as f64).sum::<f64>() / test.n as f64;
    let base_mse = test
        .y
        .iter()
        .map(|&v| (v as f64 - ymean).powi(2))
        .sum::<f64>()
        / test.n as f64;
    for arch in ALL_ARCHS {
        let t = trainer(4);
        let (model, _) = t.train(arch, &train, M, SEED).unwrap();
        let rmse = t.rmse(&model, &test).unwrap();
        let mse = rmse * rmse;
        assert!(mse.is_finite(), "{}: non-finite test MSE", arch.name());
        assert!(
            mse < ceiling(arch),
            "{}: test MSE {mse} above ceiling {}",
            arch.name(),
            ceiling(arch)
        );
        assert!(
            mse < base_mse,
            "{}: test MSE {mse} not better than mean predictor {base_mse}",
            arch.name()
        );
    }
}

/// f32-wire trainer: Gram strategy streaming H over the mixed-precision
/// kernels (`gram_widen`/`t_matvec_widen`).
fn mixed_trainer(workers: usize) -> CpuElmTrainer {
    let mut t = CpuElmTrainer::with_policy(
        ParallelPolicy::with_workers(workers).with_precision(Precision::MixedF32),
    );
    t.strategy = SolveStrategy::Gram;
    t.block_rows = 64;
    t
}

#[test]
fn f32_wire_beta_bit_identical_across_worker_counts_all_archs() {
    // the mixed-precision acceptance: the f32-wire Gram pipeline must be
    // just as worker-count-invariant as the f64 one, for all six archs
    let (train, _test) = prepared();
    for arch in ALL_ARCHS {
        let mut base: Option<Vec<f64>> = None;
        for workers in [1usize, 2, 4, 8] {
            let (model, bd) = mixed_trainer(workers).train(arch, &train, M, SEED).unwrap();
            assert!(bd.blocks > 0);
            match &base {
                None => base = Some(model.beta),
                Some(b) => assert_eq!(
                    b,
                    &model.beta,
                    "{}: f32-wire β bits differ at workers={workers}",
                    arch.name()
                ),
            }
        }
    }
}

#[test]
fn f32_wire_trainer_stays_below_mse_ceilings_all_archs() {
    // same shared per-arch ceilings as the f64 path: the f32 wire must not
    // cost model quality (H entries are f32 tanh outputs — the wire is
    // exact)
    let (train, test) = prepared();
    let ymean = test.y.iter().map(|&v| v as f64).sum::<f64>() / test.n as f64;
    let base_mse = test
        .y
        .iter()
        .map(|&v| (v as f64 - ymean).powi(2))
        .sum::<f64>()
        / test.n as f64;
    for arch in ALL_ARCHS {
        let t = mixed_trainer(4);
        let (model, _) = t.train(arch, &train, M, SEED).unwrap();
        let rmse = t.rmse(&model, &test).unwrap();
        let mse = rmse * rmse;
        assert!(mse.is_finite(), "{}: non-finite f32-wire MSE", arch.name());
        assert!(
            mse < ceiling(arch),
            "{}: f32-wire test MSE {mse} above ceiling {}",
            arch.name(),
            ceiling(arch)
        );
        assert!(
            mse < base_mse,
            "{}: f32-wire test MSE {mse} not better than mean predictor {base_mse}",
            arch.name()
        );
    }
}

/// f32-wire trainer on an arbitrary strategy (the f32-born blocks feed
/// whichever reduction the strategy selects).
fn mixed_trainer_with(workers: usize, strategy: SolveStrategy) -> CpuElmTrainer {
    let mut t = CpuElmTrainer::with_policy(
        ParallelPolicy::with_workers(workers).with_precision(Precision::MixedF32),
    );
    t.strategy = strategy;
    t.block_rows = 64;
    t
}

#[test]
fn f32_born_tsqr_beta_bit_identical_across_worker_counts_all_archs() {
    // the new f32-leaf TSQR reduction must be just as worker-invariant as
    // the f64 tree (same fixed topology; leaves widen exactly)
    let (train, _test) = prepared();
    for arch in ALL_ARCHS {
        let mut base: Option<Vec<f64>> = None;
        for workers in [1usize, 2, 4, 8] {
            let (model, _) = mixed_trainer_with(workers, SolveStrategy::Tsqr)
                .train(arch, &train, M, SEED)
                .unwrap();
            match &base {
                None => base = Some(model.beta),
                Some(b) => assert_eq!(
                    b,
                    &model.beta,
                    "{}: f32-leaf TSQR β bits differ at workers={workers}",
                    arch.name()
                ),
            }
        }
    }
}

#[test]
fn f32_born_direct_qr_bit_identical_to_sequential_lstsq_qr() {
    // strongest acceptance anchor: the f32-born pipeline widens exactly,
    // so even under MixedF32 the DirectQr β must reproduce the
    // sequential f64 lstsq_qr on the f64-assembled H, bit for bit
    let (train, _test) = prepared();
    let y: Vec<f64> = train.y.iter().map(|&v| v as f64).collect();
    for arch in [Arch::Fc, Arch::Elman, Arch::Jordan, Arch::Lstm, Arch::Gru] {
        let params = ElmParams::init(arch, train.s, train.q, M, SEED);
        let h = hidden_matrix(&params, &train, None);
        let seq = lstsq_qr(&h, &y).unwrap();
        let (model, _) = mixed_trainer_with(4, SolveStrategy::DirectQr)
            .train(arch, &train, M, SEED)
            .unwrap();
        assert_eq!(
            model.beta,
            seq,
            "{}: f32-born DirectQr β != sequential lstsq_qr",
            arch.name()
        );
    }
}

/// Trainer with the sequence-parallel recurrence engine switched on.
fn chunked_trainer(workers: usize, precision: Precision, warmup: usize) -> CpuElmTrainer {
    let mut t = CpuElmTrainer::with_policy(
        ParallelPolicy::with_workers(workers)
            .with_precision(precision)
            .with_recurrence(RecurrenceMode::Chunked { chunk: 3, warmup }),
    );
    t.strategy = SolveStrategy::DirectQr;
    t.block_rows = 64;
    t
}

#[test]
fn chunked_mode_with_full_warmup_pins_sequential_beta_bits_all_archs() {
    // chunk = 3 over Q = 8 → chunks (0,3) (3,6) (6,8), tail start 6. A
    // warm-up ≥ 6 reaches t = 0, so the stateful kernels run their exact
    // sequential loop; FC is exact by construction and Jordan/NARMAX are
    // recurrence-free. Every arch must reproduce the Sequential-mode β
    // bits, on both precision wires, at several worker counts.
    let (train, _test) = prepared();
    for precision in [Precision::F64, Precision::MixedF32] {
        for arch in ALL_ARCHS {
            let mut seq_t = CpuElmTrainer::with_policy(
                ParallelPolicy::with_workers(4).with_precision(precision),
            );
            seq_t.strategy = SolveStrategy::DirectQr;
            seq_t.block_rows = 64;
            let (seq, _) = seq_t.train(arch, &train, M, SEED).unwrap();
            for workers in [1usize, 4] {
                let (model, _) = chunked_trainer(workers, precision, Q)
                    .train(arch, &train, M, SEED)
                    .unwrap();
                assert_eq!(
                    model.beta,
                    seq.beta,
                    "{}: chunked full-warmup β != sequential bits ({precision:?}, workers={workers})",
                    arch.name()
                );
            }
        }
    }
}

#[test]
fn chunked_mode_with_truncated_warmup_keeps_model_quality() {
    // warmup = 4 < tail start 6 → the stateful archs really truncate
    // (warm start at t = 2). FC stays bit-exact regardless; the truncated
    // archs must still train to a finite MSE within 2× the per-arch
    // ceiling — the warm-up envelope costs accuracy, never sanity.
    let (train, test) = prepared();
    let seq_beta = trainer(4).train(Arch::Fc, &train, M, SEED).unwrap().0.beta;
    let fc = chunked_trainer(4, Precision::F64, 4)
        .train(Arch::Fc, &train, M, SEED)
        .unwrap()
        .0;
    assert_eq!(fc.beta, seq_beta, "FC chunked β must ignore the warm-up");
    for arch in ALL_ARCHS {
        let t = chunked_trainer(4, Precision::F64, 4);
        let (model, _) = t.train(arch, &train, M, SEED).unwrap();
        assert!(
            model.beta.iter().all(|v| v.is_finite()),
            "{}: non-finite chunked β",
            arch.name()
        );
        let rmse = t.rmse(&model, &test).unwrap();
        let mse = rmse * rmse;
        assert!(mse.is_finite(), "{}: non-finite chunked MSE", arch.name());
        assert!(
            mse < ceiling(arch) * 2.0,
            "{}: chunked test MSE {mse} above 2× ceiling {}",
            arch.name(),
            ceiling(arch) * 2.0
        );
    }
}

#[test]
fn tsqr_and_direct_qr_strategies_agree() {
    // the streaming-exact TSQR fold and the direct QR solve the same
    // least-squares problem: β must agree to factorization rounding
    let (train, _test) = prepared();
    for arch in [Arch::Elman, Arch::Gru] {
        let direct = trainer(4).train(arch, &train, M, SEED).unwrap().0;
        let mut t = CpuElmTrainer::new(4);
        t.strategy = SolveStrategy::Tsqr;
        t.block_rows = 64;
        let tsqr = t.train(arch, &train, M, SEED).unwrap().0;
        let worst = direct
            .beta
            .iter()
            .zip(&tsqr.beta)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(worst < 1e-6, "{}: |direct - tsqr| = {worst}", arch.name());
    }
}
