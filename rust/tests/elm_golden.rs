//! Golden behaviour of the sequential ELM across architectures on the
//! Table-3 benchmark generators (scaled): every architecture must learn
//! every dataset clearly better than the mean predictor, and repeated runs
//! (different random weights) must stay in a tight RMSE band — the paper's
//! §7.3 robustness claim.

use opt_pr_elm::data::spec::registry;
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::data::{MinMax, Stats};
use opt_pr_elm::elm::{SrElmModel, TrainOptions, ALL_ARCHS};

/// Windowed + normalized mini version of a Table-3 dataset.
fn prepare(name: &str, scale: f64, seed: u64) -> (Windowed, Windowed) {
    let spec = registry().into_iter().find(|d| d.name == name).unwrap();
    let series = spec.generate(scale, seed);
    let split_at = (series.len() as f64 * spec.train_frac()) as usize;
    let norm = MinMax::fit(&series[..split_at]).unwrap();
    let z = norm.apply_all(&series);
    let w = Windowed::from_series(&z, spec.q.min(10)).unwrap();
    w.split(spec.train_frac())
}

#[test]
fn every_arch_learns_every_dataset() {
    // Heavy-tailed generators (japan_population, exoplanet, stock_prices)
    // have piecewise level jumps, so one-step error-feedback models can
    // trail the mean predictor on the shifted test segment; the hard bound
    // is a loose 5× sanity ceiling and the substantive claim is the
    // majority-win condition below.
    let mut wins = 0usize;
    let mut total = 0usize;
    for spec in registry() {
        let (train, test) = prepare(spec.name, 0.05, 7);
        let ymean = test.y.iter().map(|&v| v as f64).sum::<f64>() / test.n as f64;
        let base = (test
            .y
            .iter()
            .map(|&v| (v as f64 - ymean).powi(2))
            .sum::<f64>()
            / test.n as f64)
            .sqrt();
        for arch in ALL_ARCHS {
            let model = SrElmModel::train(arch, &train, &TrainOptions::new(10, 3)).unwrap();
            let rmse = model.rmse(&test);
            assert!(
                rmse.is_finite() && rmse < base * 5.0,
                "{}/{}: rmse {rmse} vs mean-baseline {base}",
                spec.name,
                arch.name()
            );
            total += 1;
            if rmse < base {
                wins += 1;
            }
        }
    }
    assert!(
        wins * 10 >= total * 7,
        "model beats the mean predictor on only {wins}/{total} pairs"
    );
}

#[test]
fn rmse_is_robust_across_random_seeds() {
    // §7.3: random init must not swing accuracy wildly (tight std band)
    let (train, test) = prepare("aemo", 0.05, 11);
    for arch in ALL_ARCHS {
        let rmses: Vec<f64> = (0..5)
            .map(|s| {
                SrElmModel::train(arch, &train, &TrainOptions::new(10, 100 + s))
                    .unwrap()
                    .rmse(&test)
            })
            .collect();
        let s = Stats::of(&rmses);
        assert!(
            s.std() < s.mean() * 0.6,
            "{}: rmse unstable: mean {} std {} ({rmses:?})",
            arch.name(),
            s.mean(),
            s.std()
        );
    }
}

#[test]
fn larger_m_does_not_hurt_training_fit() {
    let (train, _test) = prepare("quebec_births", 0.05, 5);
    for arch in ALL_ARCHS {
        // NARMAX predicts through self-generated residuals, so its
        // prediction error is not the least-squares fit the monotonicity
        // argument applies to — skip it here (covered by every_arch test).
        if arch == opt_pr_elm::elm::Arch::Narmax {
            continue;
        }
        let r_small = SrElmModel::train(arch, &train, &TrainOptions::new(5, 2))
            .unwrap()
            .rmse(&train);
        let r_big = SrElmModel::train(arch, &train, &TrainOptions::new(40, 2))
            .unwrap()
            .rmse(&train);
        // more random features can only improve the least-squares fit
        // (up to solver noise)
        assert!(
            r_big <= r_small * 1.10 + 1e-6,
            "{}: train rmse M=40 {r_big} vs M=5 {r_small}",
            arch.name()
        );
    }
}
