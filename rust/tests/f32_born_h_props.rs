//! Edge-case conformance for the accumulate-widen kernels and the
//! **f32-born H** path (ISSUE 4):
//!
//! * degenerate and tall-skinny shapes (0×n, 1×1, deep-k) for
//!   `matmul_widen` / `gram_widen` / `t_matvec_widen` / `matvec_widen`,
//!   pinned bitwise to their f64 twins on the widened operands (the
//!   exactness half of the `linalg::matrix32` contract, mirrored on
//!   `tests/linalg_threaded_props.rs`),
//! * NaN/inf propagation through every widen kernel (no zero-skip
//!   branches anywhere in the substrate),
//! * the f32-born `h_block_f32` kernels value-anchored to the
//!   independent scalar `h_row` oracle for all six architectures (the
//!   same Algorithm-1 bound the old f64 kernels were held to), with the
//!   lossless `h_block`/`from_matrix` round-trip kept as a wiring smoke,
//!   plus the `HBlock`/`hidden_matrix_prec` dispatch carrying the same
//!   values on either wire,
//! * the promoted public-boundary shape checks firing in release builds.

use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::elm::arch::{
    h_block, h_block_f32, h_block_prec, h_block_range, h_block_range_prec, HBlock,
    SampleBlock,
};
use opt_pr_elm::elm::trainer::{hidden_matrix, hidden_matrix_prec};
use opt_pr_elm::elm::{ElmParams, ALL_ARCHS};
use opt_pr_elm::linalg::{Matrix, MatrixF32, ParallelPolicy, Precision};
use opt_pr_elm::testing::prop;
use opt_pr_elm::util::rng::Rng;

fn random_f32_matrix(g: &mut prop::Gen, rows: usize, cols: usize) -> MatrixF32 {
    let mut rng = Rng::new(g.u64());
    MatrixF32::from_matrix(&Matrix::random(rows, cols, &mut rng))
}

#[test]
fn widen_matvecs_edge_shapes_bit_identical_to_f64_property() {
    prop::check(30, |g| {
        let (rows, cols) = match g.case % 4 {
            0 => (0, 1 + g.size(0, 8)),               // 0×n
            1 => (1, 1),                              // 1×1
            2 => (200 + g.size(0, 600), 1 + g.size(0, 6)), // tall-skinny
            _ => (1 + g.size(0, 60), 1 + g.size(0, 40)),
        };
        let a = random_f32_matrix(g, rows, cols);
        let a64 = a.to_f64();
        let v: Vec<f64> = (0..cols).map(|i| (i as f64 * 0.31).cos()).collect();
        prop::assert_prop(
            a.matvec_widen(&v) == a64.matvec(&v),
            format!("matvec_widen {rows}x{cols} != f64 matvec"),
        )?;
        let w: Vec<f64> = (0..rows).map(|i| (i as f64 * 0.17).sin()).collect();
        prop::assert_prop(
            a.t_matvec_widen(&w) == a64.t_matvec(&w),
            format!("t_matvec_widen {rows}x{cols} != f64 t_matvec"),
        )
    });
}

#[test]
fn widen_gemm_and_gram_edge_shapes_bit_identical_to_f64_property() {
    prop::check(30, |g| {
        let (m, k, n) = match g.case % 4 {
            0 => (0, 1 + g.size(0, 8), 1 + g.size(0, 8)),
            1 => (1, 1, 1),
            2 => (1 + g.size(0, 6), 200 + g.size(0, 400), 1 + g.size(0, 6)), // deep k
            _ => (200 + g.size(0, 600), 1 + g.size(0, 5), 1 + g.size(0, 12)), // tall
        };
        let a = random_f32_matrix(g, m, k);
        let b = random_f32_matrix(g, k, n);
        prop::assert_prop(
            a.matmul_widen(&b, ParallelPolicy::sequential()) == a.to_f64().matmul(&b.to_f64()),
            format!("matmul_widen {m}x{k}x{n} != f64 GEMM"),
        )?;
        prop::assert_prop(
            a.gram_widen(ParallelPolicy::sequential())
                == a.to_f64().gram_with(ParallelPolicy::sequential()),
            format!("gram_widen {m}x{k} != f64 gram"),
        )
    });
}

#[test]
fn widen_kernels_propagate_non_finite() {
    // inf × 0 must surface as NaN through every widen kernel (no
    // zero-skip branches), matching the f64 substrate's behavior
    let a = MatrixF32::from_vec(2, 2, vec![0.0, 1.0, f32::INFINITY, 2.0]);
    let b = MatrixF32::from_vec(2, 1, vec![f32::INFINITY, 0.5]);
    let c = a.matmul_widen(&b, ParallelPolicy::sequential());
    assert!(c[(0, 0)].is_nan(), "matmul_widen skipped 0*inf: {}", c[(0, 0)]);
    let g = MatrixF32::from_vec(3, 2, vec![0.0, f32::NAN, 1.0, 1.0, 2.0, 3.0])
        .gram_widen(ParallelPolicy::sequential());
    assert!(g.data().iter().any(|v| v.is_nan()), "gram_widen dropped NaN");
    let t = MatrixF32::from_vec(2, 2, vec![f32::INFINITY, 1.0, 2.0, 3.0]);
    let tv = t.t_matvec_widen(&[0.0, 1.0]);
    assert!(tv[0].is_nan(), "t_matvec_widen skipped inf*0: {}", tv[0]);
    assert!((tv[1] - 3.0).abs() < 1e-12, "t_matvec_widen[1]: {}", tv[1]);
    let mv = t.matvec_widen(&[0.0, 1.0]);
    assert!(mv[0].is_nan(), "matvec_widen skipped inf*0: {}", mv[0]);
    assert!((mv[1] - 3.0).abs() < 1e-12, "matvec_widen[1]: {}", mv[1]);
}

fn toy_windowed(n: usize, q: usize, seed: u64) -> Windowed {
    let mut rng = Rng::new(seed);
    let mut y = vec![0.3f64, 0.45];
    for t in 2..n + q {
        let v = 0.5 * y[t - 1] + 0.2 * y[t - 2]
            + 0.1 * (t as f64 * 0.19).sin()
            + 0.05 * rng.normal();
        y.push(v);
    }
    let lo = y.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = y.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let z: Vec<f64> = y.iter().map(|v| (v - lo) / (hi - lo)).collect();
    Windowed::from_series(&z, q).unwrap()
}

#[test]
fn f32_born_h_matches_scalar_oracle_and_round_trips_all_archs() {
    let (s, q, m) = (2, 5, 6);
    let rows = 11; // odd: exercises the 4-wide lockstep AND scalar tails
    let mut rng = Rng::new(42);
    let x: Vec<f32> = rng.normals_f32(rows * s * q);
    let yh: Vec<f32> = rng.normals_f32(rows * q).iter().map(|v| v * 0.1).collect();
    let eh: Vec<f32> = rng.normals_f32(rows * q).iter().map(|v| v * 0.1).collect();
    let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };
    let mut out = vec![0f32; m];
    for arch in ALL_ARCHS {
        let p = ElmParams::init(arch, s, q, m, 7);
        let born = h_block_f32(&p, &blk);
        assert_eq!((born.rows, born.cols), (rows, m), "{arch:?}");
        // the value anchor is the INDEPENDENT scalar recurrence (h_row):
        // the f32-born kernel must agree with Algorithm 1 per sample to
        // the lifted-GEMM association bound, same as the old f64 kernel
        for i in 0..rows {
            opt_pr_elm::elm::arch::h_row(
                &p,
                &x[i * s * q..(i + 1) * s * q],
                &yh[i * q..(i + 1) * q],
                &eh[i * q..(i + 1) * q],
                &mut out,
            );
            for j in 0..m {
                assert!(
                    (born[(i, j)] - out[j]).abs() < 1e-5,
                    "{arch:?} row {i} col {j}: {} vs h_row {}",
                    born[(i, j)],
                    out[j]
                );
            }
        }
        // dispatch smoke (holds by construction now that h_block is the
        // widening wrapper — kept to pin that wiring, not the values):
        // the f64 entry point is the exact widening of the f32 block and
        // rounding it back is lossless
        let widened = h_block(&p, &blk);
        assert_eq!(born.to_f64(), widened, "{arch:?}: h_block not the exact widen");
        assert_eq!(
            born,
            MatrixF32::from_matrix(&widened),
            "{arch:?}: round-trip not lossless"
        );
    }
}

#[test]
fn h_block_prec_dispatch_carries_identical_values_on_either_wire() {
    let (s, q, m) = (1, 4, 5);
    let rows = 9;
    let mut rng = Rng::new(3);
    let x: Vec<f32> = rng.normals_f32(rows * s * q);
    let yh = vec![0f32; rows * q];
    let eh = vec![0f32; rows * q];
    let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };
    let beta: Vec<f64> = (0..m).map(|j| (j as f64 * 0.4).cos()).collect();
    for arch in ALL_ARCHS {
        let p = ElmParams::init(arch, s, q, m, 11);
        let f64b = h_block_prec(&p, &blk, Precision::F64);
        let f32b = h_block_prec(&p, &blk, Precision::MixedF32);
        assert!(matches!(f64b, HBlock::F64(_)));
        assert!(matches!(f32b, HBlock::F32(_)));
        assert_eq!((f64b.rows(), f64b.cols()), (rows, m));
        assert_eq!((f32b.rows(), f32b.cols()), (rows, m));
        // predictions are wire-independent (matvec vs matvec_widen on
        // f32-representable entries)
        assert_eq!(f64b.matvec(&beta), f32b.matvec(&beta), "{arch:?}");
        assert_eq!(f64b.into_f64(), f32b.into_f64(), "{arch:?}");
    }
}

#[test]
fn hidden_matrix_prec_f32_wire_is_exact_for_all_archs() {
    let w = toy_windowed(300, 6, 8);
    for arch in ALL_ARCHS {
        let p = ElmParams::init(arch, w.s, w.q, 8, 5);
        let h64 = hidden_matrix(&p, &w, None);
        let h32 = match hidden_matrix_prec(&p, &w, None, Precision::MixedF32) {
            HBlock::F32(h) => h,
            HBlock::F64(_) => panic!("MixedF32 returned an f64 matrix"),
        };
        assert_eq!(h32.to_f64(), h64, "{arch:?}: f32-wire H differs");
        assert_eq!(h32, MatrixF32::from_matrix(&h64), "{arch:?}: rounding differs");
    }
}

#[test]
fn h_block_range_prec_matches_unranged_kernels() {
    let w = toy_windowed(100, 5, 9);
    for arch in ALL_ARCHS {
        let p = ElmParams::init(arch, w.s, w.q, 6, 2);
        let full = hidden_matrix(&p, &w, None);
        let part = h_block_range(&p, &w, None, 32, 80);
        for r in 0..80 - 32 {
            assert_eq!(part.row(r), full.row(32 + r), "{arch:?} row {r}");
        }
        match h_block_range_prec(&p, &w, None, 32, 80, Precision::MixedF32) {
            HBlock::F32(hf) => assert_eq!(hf.to_f64(), part, "{arch:?}"),
            HBlock::F64(_) => panic!("MixedF32 range returned f64"),
        }
    }
}

#[test]
#[should_panic(expected = "ehist has")]
fn h_block_range_rejects_short_ehist_in_release_builds_too() {
    // promoted from debug_assert: must fire with a descriptive message
    // whatever the build profile
    let w = toy_windowed(50, 4, 1);
    let p = ElmParams::init(opt_pr_elm::elm::Arch::Narmax, w.s, w.q, 4, 1);
    let short = vec![0f32; 10 * w.q]; // dataset needs n*q = 200
    let _ = h_block_range(&p, &w, Some(&short), 0, w.n);
}

#[test]
#[should_panic(expected = "SampleBlock.x")]
fn h_block_rejects_mis_sized_sample_block_in_release_builds_too() {
    let p = ElmParams::init(opt_pr_elm::elm::Arch::Elman, 2, 4, 3, 1);
    let x = vec![0f32; 7]; // rows*s*q = 16 expected
    let yh = vec![0f32; 8];
    let eh = vec![0f32; 8];
    let blk = SampleBlock { rows: 2, x: &x, yhist: &yh, ehist: &eh };
    let _ = h_block(&p, &blk);
}
