//! Fault-injection matrix: every fault class, at every site it applies
//! to, through every solve strategy, at every worker count the CI matrix
//! runs (`FAULT_MATRIX_WORKERS`).
//!
//! The contract under test (the robustness invariant): an injected run
//! either returns a **finite β with a [`SolveReport`] rung** explaining
//! how it recovered, or a **typed [`SolveError`]** — never a silent NaN
//! β and never a propagated worker panic. And because fire decisions are
//! keyed by (seed, block index) — not worker count — the *outcome* (β
//! bits on recovery, error class on failure) is identical at any worker
//! count.
//!
//! Only compiled with `--features fault-inject`; the plain test build
//! carries none of this.

#![cfg(feature = "fault-inject")]

use opt_pr_elm::coordinator::accumulator::SolveStrategy;
use opt_pr_elm::coordinator::pipeline::CpuElmTrainer;
use opt_pr_elm::coordinator::{
    FleetOutcome, FleetRequest, FleetService, FleetTrainer, ServiceConfig,
};
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::elm::Arch;
use opt_pr_elm::linalg::RecurrenceMode;
use opt_pr_elm::robust::inject::{
    arm, corrupt_slice_f64, deadline_skew, take_events, Fault, FaultPlan, Site,
};
use opt_pr_elm::robust::{as_solve_error, DegradationRung};
use opt_pr_elm::util::rng::Rng;

const STRATEGIES: [SolveStrategy; 3] =
    [SolveStrategy::Gram, SolveStrategy::Tsqr, SolveStrategy::DirectQr];

/// Worker counts to sweep: the CI fault-matrix job pins one count per
/// matrix leg via `FAULT_MATRIX_WORKERS`; an unset env sweeps both a
/// sequential and a parallel schedule locally.
fn worker_counts() -> Vec<usize> {
    match std::env::var("FAULT_MATRIX_WORKERS") {
        Ok(v) => vec![v.parse().expect("FAULT_MATRIX_WORKERS must be a number")],
        Err(_) => vec![1, 4],
    }
}

fn toy_windowed(n: usize, q: usize, seed: u64) -> Windowed {
    let mut rng = Rng::new(seed);
    let mut y = vec![0.3f64, 0.45];
    for t in 2..n + q {
        let v = 0.5 * y[t - 1] + 0.22 * y[t - 2]
            + 0.12 * (t as f64 * 0.17).sin()
            + 0.05 * rng.normal();
        y.push(v);
    }
    Windowed::from_series(&y, q).unwrap()
}

fn trainer(workers: usize, strategy: SolveStrategy) -> CpuElmTrainer {
    let mut t = CpuElmTrainer::new(workers);
    t.strategy = strategy;
    t.block_rows = 64;
    t
}

/// Worker-count-invariant signature of one injected run: β bits + rung on
/// recovery, or the typed error class on failure.
#[derive(Debug, PartialEq)]
enum Outcome {
    Recovered { beta: Vec<f64>, rung: &'static str, quarantined: usize },
    TypedError { class: &'static str },
}

/// Run one injected training and enforce the contract: finite β with a
/// rung, or a typed error — and the injection actually fired.
fn run_contract(
    plan: FaultPlan,
    strategy: SolveStrategy,
    workers: usize,
    w: &Windowed,
) -> Outcome {
    contract_outcome(trainer(workers, strategy), plan, w, &format!("{strategy:?} w={workers}"))
}

/// The contract body, generic over the trainer (sequential- or
/// chunked-recurrence) so the ScanChunk legs share the same enforcement.
fn contract_outcome(t: CpuElmTrainer, plan: FaultPlan, w: &Windowed, ctx: &str) -> Outcome {
    let guard = arm(plan);
    let out = t.train(Arch::Elman, w, 8, 3);
    let events = take_events();
    drop(guard);
    assert!(
        !events.is_empty(),
        "{plan:?}/{ctx}: campaign never fired (vacuous test)"
    );
    assert!(events.iter().all(|e| e.site == plan.site && e.fault == plan.fault));
    match out {
        Ok((model, bd)) => {
            assert!(
                model.beta.iter().all(|b| b.is_finite()),
                "{plan:?}/{ctx}: Ok with non-finite β — \
                 the exact silent poisoning the harness exists to catch"
            );
            assert_ne!(bd.solve_report.rung, DegradationRung::Failed);
            Outcome::Recovered {
                beta: model.beta,
                rung: bd.solve_report.rung_name(),
                quarantined: bd.solve_report.quarantined_rows,
            }
        }
        Err(e) => {
            let se = as_solve_error(&e).unwrap_or_else(|| {
                panic!("{plan:?}/{ctx}: stringly error: {e}")
            });
            Outcome::TypedError { class: se.class() }
        }
    }
}

/// The full site × fault × strategy sweep: every leg honors the contract,
/// and the outcome is identical at every worker count.
#[test]
fn fault_matrix_honors_the_contract_at_every_worker_count() {
    let w = toy_windowed(260, 6, 1);
    let plans = [
        (Site::DataWindow, Fault::NanPayload),
        (Site::DataWindow, Fault::InfPayload),
        (Site::HBlock, Fault::NanPayload),
        (Site::HBlock, Fault::InfPayload),
        (Site::HBlock, Fault::DenormalScale),
        (Site::HBlock, Fault::DuplicateColumns),
        (Site::HBlock, Fault::ConstantColumn),
        (Site::HBlock, Fault::TruncateRows),
        (Site::TsqrLeaf, Fault::NanPayload),
        (Site::Worker, Fault::WorkerPanic),
    ];
    for (site, fault) in plans {
        for strategy in STRATEGIES {
            // the TSQR-leaf site only exists on the TSQR path
            if site == Site::TsqrLeaf && strategy != SolveStrategy::Tsqr {
                continue;
            }
            // period 1: fire at every index — a sparser period on a
            // 5-block dataset could deterministically never fire, which
            // the vacuousness assert below would (correctly) reject
            let plan = FaultPlan { seed: 42, site, fault, period: 1 };
            let mut base: Option<Outcome> = None;
            for workers in worker_counts() {
                let out = run_contract(plan, strategy, workers, &w);
                match &base {
                    None => base = Some(out),
                    Some(b) => assert_eq!(
                        b, &out,
                        "{site:?}/{fault:?}/{strategy:?}: outcome differs at \
                         workers={workers}"
                    ),
                }
            }
        }
    }
}

/// Gram-partial corruption is Gram-strategy-specific: a NaN partial can
/// never survive the ladder's finiteness gate, so the run must end in a
/// typed ladder exhaustion — not a NaN β.
#[test]
fn nan_gram_partial_is_a_typed_ladder_exhaustion() {
    let w = toy_windowed(260, 6, 2);
    for workers in worker_counts() {
        let plan = FaultPlan {
            seed: 7,
            site: Site::GramPartial,
            fault: Fault::NanPayload,
            period: 1,
        };
        let out = run_contract(plan, SolveStrategy::Gram, workers, &w);
        assert_eq!(
            out,
            Outcome::TypedError { class: "ladder-exhausted" },
            "workers={workers}"
        );
    }
}

/// A poisoned TSQR leaf must be *recovered from*: the R-factor verdict
/// flags the non-finite diagonal and the trainer re-solves through the
/// ridge ladder on clean, recomputed Gram partials.
#[test]
fn poisoned_tsqr_leaf_recovers_through_the_ridge_ladder() {
    let w = toy_windowed(260, 6, 3);
    for workers in worker_counts() {
        let plan = FaultPlan {
            seed: 11,
            site: Site::TsqrLeaf,
            fault: Fault::NanPayload,
            period: 1,
        };
        let out = run_contract(plan, SolveStrategy::Tsqr, workers, &w);
        match out {
            Outcome::Recovered { rung, .. } => {
                assert_eq!(rung, "ridge", "workers={workers}")
            }
            other => panic!("expected ridge recovery, got {other:?}"),
        }
    }
}

/// A corrupted data window is the quarantine's job: the poisoned rows are
/// screened out, counted in the report, and training succeeds on the rest.
#[test]
fn corrupted_window_rows_are_quarantined_and_counted() {
    let w = toy_windowed(260, 6, 4);
    for strategy in STRATEGIES {
        for workers in worker_counts() {
            let plan = FaultPlan {
                seed: 13,
                site: Site::DataWindow,
                fault: Fault::NanPayload,
                period: 1,
            };
            let out = run_contract(plan, strategy, workers, &w);
            match out {
                Outcome::Recovered { quarantined, .. } => assert!(
                    quarantined > 0,
                    "{strategy:?} w={workers}: NaN window rows must be counted"
                ),
                other => panic!("{strategy:?}: expected recovery, got {other:?}"),
            }
        }
    }
}

/// Injected worker panics are isolated, retried once sequentially, and
/// reported — and because the retry recomputes the identical block, β is
/// bit-identical to the healthy run.
#[test]
fn injected_worker_panics_are_retried_to_a_bit_identical_beta() {
    let w = toy_windowed(260, 6, 5);
    for strategy in STRATEGIES {
        for workers in worker_counts() {
            // healthy reference (no plan armed)
            let (healthy, _) =
                trainer(workers, strategy).train(Arch::Elman, &w, 8, 3).unwrap();
            let plan = FaultPlan {
                seed: 17,
                site: Site::Worker,
                fault: Fault::WorkerPanic,
                period: 1,
            };
            let guard = arm(plan);
            let res = trainer(workers, strategy).train(Arch::Elman, &w, 8, 3);
            let events = take_events();
            drop(guard);
            assert!(!events.is_empty(), "panic campaign never fired");
            let (model, bd) = res.unwrap_or_else(|e| {
                panic!("{strategy:?} w={workers}: panic leaked as error: {e}")
            });
            assert!(
                bd.solve_report.retries >= events.len() as u32,
                "{strategy:?} w={workers}: {} panics but only {} retries reported",
                events.len(),
                bd.solve_report.retries
            );
            assert_eq!(
                model.beta, healthy.beta,
                "{strategy:?} w={workers}: retried β must match the healthy bits"
            );
        }
    }
}

/// Trainer with the sequence-parallel recurrence engine on — the
/// `ScanChunk` site only exists on the chunked path. chunk = 3 over
/// Q = 6 → two chunks per block, tail chunk index 1; warmup = 6 reaches
/// t = 0, so the healthy chunked values are the sequential bits and an
/// armed fault changes *only* what it injects.
fn chunked_trainer(workers: usize, strategy: SolveStrategy) -> CpuElmTrainer {
    let mut t = trainer(workers, strategy);
    t.policy = t
        .policy
        .with_recurrence(RecurrenceMode::Chunked { chunk: 3, warmup: 6 });
    t
}

/// The ScanChunk legs of the fault matrix: payload corruption, row
/// truncation, and chunk-keyed panics on the chunked kernel output all
/// honor the robustness contract, with outcomes identical at 1 and 8 (or
/// whatever the CI matrix pins) workers — fire decisions are keyed by
/// chunk index, never by schedule.
#[test]
fn scan_chunk_faults_honor_the_contract_at_every_worker_count() {
    let w = toy_windowed(260, 6, 6);
    let faults = [
        Fault::NanPayload,
        Fault::InfPayload,
        Fault::TruncateRows,
        Fault::WorkerPanic,
    ];
    for fault in faults {
        for strategy in STRATEGIES {
            let plan = FaultPlan { seed: 23, site: Site::ScanChunk, fault, period: 1 };
            let mut base: Option<Outcome> = None;
            for workers in worker_counts() {
                let out = contract_outcome(
                    chunked_trainer(workers, strategy),
                    plan,
                    &w,
                    &format!("chunked {strategy:?} w={workers}"),
                );
                match &base {
                    None => base = Some(out),
                    Some(b) => assert_eq!(
                        b, &out,
                        "ScanChunk/{fault:?}/{strategy:?}: outcome differs at \
                         workers={workers}"
                    ),
                }
            }
        }
    }
}

/// The ScanChunk site never fires on the sequential recurrence path: a
/// plan armed against a `RecurrenceMode::Sequential` trainer is inert
/// (and the values are untouched) — the site is strictly chunked-only.
#[test]
fn scan_chunk_site_is_inert_on_the_sequential_path() {
    let w = toy_windowed(260, 6, 7);
    let (healthy, _) =
        trainer(1, SolveStrategy::DirectQr).train(Arch::Elman, &w, 8, 3).unwrap();
    let plan = FaultPlan {
        seed: 23,
        site: Site::ScanChunk,
        fault: Fault::NanPayload,
        period: 1,
    };
    let guard = arm(plan);
    let res = trainer(1, SolveStrategy::DirectQr).train(Arch::Elman, &w, 8, 3);
    let events = take_events();
    drop(guard);
    assert!(events.is_empty(), "ScanChunk fired without chunked mode: {events:?}");
    assert_eq!(res.unwrap().0.beta, healthy.beta);
}

/// An injected panic at a chunk boundary is caught by the same worker
/// isolation as block-level panics, retried once (the fired set marks the
/// (site, index) so the retry runs clean), and the retried β is
/// bit-identical to the healthy chunked run at every worker count.
#[test]
fn scan_chunk_panics_are_retried_to_a_bit_identical_beta() {
    let w = toy_windowed(260, 6, 8);
    for strategy in STRATEGIES {
        for workers in worker_counts() {
            let (healthy, _) = chunked_trainer(workers, strategy)
                .train(Arch::Elman, &w, 8, 3)
                .unwrap();
            let plan = FaultPlan {
                seed: 29,
                site: Site::ScanChunk,
                fault: Fault::WorkerPanic,
                period: 1,
            };
            let guard = arm(plan);
            let res = chunked_trainer(workers, strategy).train(Arch::Elman, &w, 8, 3);
            let events = take_events();
            drop(guard);
            assert!(!events.is_empty(), "chunk panic campaign never fired");
            let (model, bd) = res.unwrap_or_else(|e| {
                panic!("chunked {strategy:?} w={workers}: panic leaked as error: {e}")
            });
            assert!(
                bd.solve_report.retries >= events.len() as u32,
                "chunked {strategy:?} w={workers}: {} panics but only {} retries",
                events.len(),
                bd.solve_report.retries
            );
            assert_eq!(
                model.beta, healthy.beta,
                "chunked {strategy:?} w={workers}: retried β must match healthy bits"
            );
        }
    }
}

// --- Fleet-job fault isolation ------------------------------------------
//
// The `FleetJob` site targets ONE tenant's work inside a grouped
// block-diagonal solve, keyed by the tenant's train-submission index in
// the drain batch. The isolation contract: a poisoned tenant fails with a
// typed per-tenant error (or recovers), and its group-mates' β stay
// bit-identical to the clean drain — at every worker count.

const FLEET_TENANTS: usize = 5;

fn fleet_reqs() -> Vec<FleetRequest> {
    (0..FLEET_TENANTS)
        .map(|i| FleetRequest::Train {
            tenant: format!("tenant-{i}"),
            arch: Arch::Elman,
            m: 8,
            seed: 100 + i as u64,
            data: toy_windowed(150 + 10 * i, 4, 50 + i as u64),
        })
        .collect()
}

fn loaded_fleet(workers: usize, reqs: &[FleetRequest]) -> FleetTrainer {
    let mut fleet = FleetTrainer::new(workers);
    fleet.block_rows = 48;
    for r in reqs {
        fleet.submit(r.clone()).unwrap();
    }
    fleet
}

/// Find a `(seed, period)` whose deterministic fire pattern over the
/// tenant indices `0..FLEET_TENANTS` is a strict non-empty subset. The
/// per-index decision is a pure function of the plan, so this probe
/// exactly predicts which tenants an armed drain will poison.
fn strict_subset_plan(fault: Fault) -> (FaultPlan, Vec<usize>) {
    for period in [2usize, 3, 5] {
        for seed in 1..40u64 {
            let plan = FaultPlan { seed, site: Site::FleetJob, fault, period };
            let guard = arm(plan);
            let fired: Vec<usize> = (0..FLEET_TENANTS)
                .filter(|&idx| {
                    let mut probe = vec![0.5f64; 16];
                    corrupt_slice_f64(Site::FleetJob, idx, &mut probe, 4, 4)
                })
                .collect();
            let _ = take_events();
            drop(guard);
            if !fired.is_empty() && fired.len() < FLEET_TENANTS {
                return (plan, fired);
            }
        }
    }
    panic!("no (seed, period) fires on a strict subset of {FLEET_TENANTS} tenants");
}

/// A NaN payload injected into a strict subset of a fleet group poisons
/// exactly those tenants — each ends in a typed per-tenant ladder
/// exhaustion and stays uncached — while every group-mate's β is
/// bit-identical to the clean drain, invariantly across worker counts.
#[test]
fn fleet_nan_payload_poisons_only_the_targeted_tenants() {
    let reqs = fleet_reqs();
    let (plan, victims) = strict_subset_plan(Fault::NanPayload);
    let mut base: Option<Vec<Option<Vec<f64>>>> = None;
    for workers in worker_counts() {
        let mut clean = loaded_fleet(workers, &reqs);
        let clean_out = clean.drain();
        assert!(
            clean_out.iter().all(|(_, o)| matches!(o, FleetOutcome::Trained { .. })),
            "workers={workers}: clean drain must train every tenant"
        );

        let mut fleet = loaded_fleet(workers, &reqs);
        let guard = arm(plan);
        let out = fleet.drain();
        let events = take_events();
        drop(guard);
        assert!(events
            .iter()
            .all(|e| e.site == Site::FleetJob && e.fault == Fault::NanPayload));
        let mut fired: Vec<usize> = events.iter().map(|e| e.index).collect();
        fired.sort_unstable();
        fired.dedup();
        assert_eq!(
            fired, victims,
            "workers={workers}: fired tenants drifted from the probe"
        );

        for (i, (tenant, o)) in out.iter().enumerate() {
            if victims.contains(&i) {
                match o {
                    FleetOutcome::Failed { error, report } => {
                        assert_eq!(
                            error.class(),
                            "ladder-exhausted",
                            "workers={workers} {tenant}"
                        );
                        assert_eq!(report.rung, DegradationRung::Failed);
                    }
                    other => panic!(
                        "workers={workers} {tenant}: expected Failed, got {other:?}"
                    ),
                }
                assert!(
                    !fleet.has_model(tenant),
                    "workers={workers} {tenant}: poisoned tenant must not be cached"
                );
            } else {
                assert!(
                    matches!(o, FleetOutcome::Trained { .. }),
                    "workers={workers} {tenant}: group-mate must train: {o:?}"
                );
                assert_eq!(
                    fleet.model(tenant).unwrap().beta,
                    clean.model(tenant).unwrap().beta,
                    "workers={workers} {tenant}: group-mate β must stay bit-identical"
                );
            }
        }

        // the whole per-tenant β signature is worker-count invariant
        let sig: Vec<Option<Vec<f64>>> = out
            .iter()
            .map(|(t, _)| fleet.model(t).map(|m| m.beta.clone()))
            .collect();
        match &base {
            None => base = Some(sig),
            Some(b) => {
                assert_eq!(b, &sig, "fleet outcome differs at workers={workers}")
            }
        }
    }
}

/// An injected panic at a tenant's first fleet block task is isolated and
/// retried by the group stream's worker isolation (the fired set marks
/// the (site, tenant) pair, so the retry runs clean): every tenant still
/// trains, the retries are reported, and every β is bit-identical to the
/// clean drain at every worker count.
#[test]
fn fleet_job_panics_are_retried_to_bit_identical_betas() {
    let reqs = fleet_reqs();
    for workers in worker_counts() {
        let mut clean = loaded_fleet(workers, &reqs);
        clean.drain();

        let mut fleet = loaded_fleet(workers, &reqs);
        let plan = FaultPlan {
            seed: 31,
            site: Site::FleetJob,
            fault: Fault::WorkerPanic,
            period: 1, // every tenant panics once
        };
        let guard = arm(plan);
        let out = fleet.drain();
        let events = take_events();
        drop(guard);
        assert_eq!(
            events.len(),
            FLEET_TENANTS,
            "workers={workers}: one panic per tenant must fire"
        );
        for (tenant, o) in &out {
            match o {
                FleetOutcome::Trained { report, .. } => {
                    assert!(
                        report.retries >= events.len() as u32,
                        "workers={workers} {tenant}: {} panics but only {} \
                         retries reported",
                        events.len(),
                        report.retries
                    );
                }
                other => panic!(
                    "workers={workers} {tenant}: panic leaked as {other:?}"
                ),
            }
            assert_eq!(
                fleet.model(tenant).unwrap().beta,
                clean.model(tenant).unwrap().beta,
                "workers={workers} {tenant}: retried β must match the clean bits"
            );
        }
    }
}

// --- Service-queue fault isolation ---------------------------------------
//
// The `ServiceQueue` site targets ONE admitted request inside the
// deadline-aware `FleetService`, keyed by its admission index — never by
// worker count or drain schedule. The isolation contract: a skewed or
// panicked request is shed (typed `deadline-exceeded`) or retried to
// success, and every other tenant's β stays bit-identical to the clean
// run, at every worker count.

fn loaded_service(workers: usize, reqs: &[FleetRequest]) -> FleetService {
    let mut trainer = FleetTrainer::new(workers);
    trainer.block_rows = 48;
    let mut svc = FleetService::with_config(trainer, ServiceConfig::default());
    for r in reqs {
        svc.submit(r.clone(), None, 0).unwrap();
    }
    svc
}

/// Find a `(seed, period)` firing on a strict non-empty subset of the
/// admission indices `0..FLEET_TENANTS`. The probe uses the side-effect
/// free `deadline_skew` hook; the fire decision depends only on
/// `(site, index, seed, period)` — never the fault — so the same plan
/// with `fault` swapped in fires on the same indices.
fn service_subset_plan(fault: Fault) -> (FaultPlan, Vec<usize>) {
    for period in [2usize, 3, 5] {
        for seed in 1..40u64 {
            let probe = FaultPlan {
                seed,
                site: Site::ServiceQueue,
                fault: Fault::DeadlineSkew,
                period,
            };
            let guard = arm(probe);
            let fired: Vec<usize> = (0..FLEET_TENANTS)
                .filter(|&i| deadline_skew(Site::ServiceQueue, i))
                .collect();
            let _ = take_events();
            drop(guard);
            if !fired.is_empty() && fired.len() < FLEET_TENANTS {
                return (
                    FaultPlan { seed, site: Site::ServiceQueue, fault, period },
                    fired,
                );
            }
        }
    }
    panic!("no (seed, period) fires on a strict subset of {FLEET_TENANTS} requests");
}

/// Injected deadline skew sheds exactly the targeted requests with a
/// typed `deadline-exceeded` — they are never trained, never cached — and
/// every unskewed tenant's β is bit-identical to the clean run, at every
/// worker count.
#[test]
fn service_deadline_skew_sheds_only_the_targeted_requests() {
    let reqs = fleet_reqs();
    let (plan, victims) = service_subset_plan(Fault::DeadlineSkew);
    let mut base: Option<Vec<Option<Vec<f64>>>> = None;
    for workers in worker_counts() {
        let mut clean = loaded_service(workers, &reqs);
        let clean_done = clean.run_to_idle();
        assert!(clean_done.iter().all(|c| c.outcome.is_ok()));

        let mut svc = loaded_service(workers, &reqs);
        let guard = arm(plan);
        let done = svc.run_to_idle();
        let events = take_events();
        drop(guard);
        assert!(!events.is_empty(), "skew campaign never fired (vacuous test)");
        assert!(events
            .iter()
            .all(|e| e.site == Site::ServiceQueue && e.fault == Fault::DeadlineSkew));

        assert_eq!(done.len(), FLEET_TENANTS);
        for (i, c) in done.iter().enumerate() {
            if victims.contains(&i) {
                let err = c.outcome.as_ref().expect_err("skewed request must be shed");
                assert_eq!(
                    err.class(),
                    "deadline-exceeded",
                    "workers={workers} {}: {err}",
                    c.tenant
                );
                assert!(
                    !svc.trainer().has_model(&c.tenant),
                    "workers={workers} {}: skewed request must never train",
                    c.tenant
                );
            } else {
                assert!(
                    matches!(c.outcome, Ok(FleetOutcome::Trained { .. })),
                    "workers={workers} {}: unskewed tenant must train: {:?}",
                    c.tenant,
                    c.outcome
                );
                assert_eq!(
                    svc.trainer().model(&c.tenant).unwrap().beta,
                    clean.trainer().model(&c.tenant).unwrap().beta,
                    "workers={workers} {}: unskewed β must stay bit-identical",
                    c.tenant
                );
            }
        }
        assert_eq!(svc.stats().deadline_miss, victims.len() as u64);

        let sig: Vec<Option<Vec<f64>>> = done
            .iter()
            .map(|c| svc.trainer().model(&c.tenant).map(|m| m.beta.clone()))
            .collect();
        match &base {
            None => base = Some(sig),
            Some(b) => {
                assert_eq!(b, &sig, "service outcome differs at workers={workers}")
            }
        }
    }
}

/// An injected panic at a request's dispatch is caught, the request is
/// re-queued with seed-keyed backoff, and the retry (the fired set marks
/// the admission index, so it runs clean) trains it to the same bits as
/// the clean run — no other tenant's β moves, at every worker count.
#[test]
fn service_queue_panics_are_retried_without_perturbing_other_tenants() {
    let reqs = fleet_reqs();
    let (plan, victims) = service_subset_plan(Fault::WorkerPanic);
    let mut base: Option<Vec<Vec<f64>>> = None;
    for workers in worker_counts() {
        let mut clean = loaded_service(workers, &reqs);
        clean.run_to_idle();

        let mut svc = loaded_service(workers, &reqs);
        let guard = arm(plan);
        let done = svc.run_to_idle();
        let events = take_events();
        drop(guard);
        assert!(!events.is_empty(), "panic campaign never fired (vacuous test)");
        let mut fired: Vec<usize> = events.iter().map(|e| e.index).collect();
        fired.sort_unstable();
        fired.dedup();
        assert_eq!(fired, victims, "workers={workers}: fired set drifted from probe");

        // every request — panicked or not — ends Trained after the retry
        assert_eq!(done.len(), FLEET_TENANTS);
        for c in &done {
            assert!(
                matches!(c.outcome, Ok(FleetOutcome::Trained { .. })),
                "workers={workers} {}: retried request must train: {:?}",
                c.tenant,
                c.outcome
            );
        }
        assert_eq!(
            svc.stats().retries,
            victims.len() as u64,
            "workers={workers}: one retry per panicked request"
        );
        for c in &done {
            assert_eq!(
                svc.trainer().model(&c.tenant).unwrap().beta,
                clean.trainer().model(&c.tenant).unwrap().beta,
                "workers={workers} {}: β must stay bit-identical through the retry",
                c.tenant
            );
        }

        let sig: Vec<Vec<f64>> = done
            .iter()
            .map(|c| svc.trainer().model(&c.tenant).unwrap().beta.clone())
            .collect();
        match &base {
            None => base = Some(sig),
            Some(b) => {
                assert_eq!(b, &sig, "service outcome differs at workers={workers}")
            }
        }
    }
}
