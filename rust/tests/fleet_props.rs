//! Fleet conformance suite: the multi-tenant `FleetTrainer`'s grouped
//! block-diagonal solves must be **bit-identical** to training each
//! tenant's model alone — for every architecture, both precision wires,
//! any worker count, ragged group sizes, and any submission order — and
//! its RLS warm-update path must match batch ridge over all rows seen.

use opt_pr_elm::coordinator::accumulator::SolveStrategy;
use opt_pr_elm::coordinator::fleet::{FleetOutcome, FleetRequest, FleetTrainer};
use opt_pr_elm::coordinator::CpuElmTrainer;
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::elm::trainer::hidden_matrix;
use opt_pr_elm::elm::{Arch, ALL_ARCHS};
use opt_pr_elm::linalg::{cholesky_solve, ParallelPolicy, Precision};
use opt_pr_elm::robust::{as_solve_error, DegradationRung, SolveError};

/// Deterministic logistic-map series: chaotic enough to keep every arch's
/// random features well-conditioned, no RNG dependency.
fn series(n: usize, seed: u64) -> Vec<f64> {
    let mut v = Vec::with_capacity(n);
    let mut x = 0.37 + (seed % 97) as f64 * 1e-3;
    for _ in 0..n {
        x = 3.7 * x * (1.0 - x);
        v.push(x - 0.5);
    }
    v
}

fn windows(n: usize, q: usize, seed: u64) -> Windowed {
    Windowed::from_series(&series(n + q, seed), q).expect("windowed")
}

fn policy(workers: usize, precision: Precision) -> ParallelPolicy {
    let mut p = ParallelPolicy::with_workers(workers);
    p.precision = precision;
    p
}

/// Solo trainer with the exact knobs the fleet under test uses.
fn solo(pol: ParallelPolicy, strategy: SolveStrategy, block_rows: usize) -> CpuElmTrainer {
    CpuElmTrainer { policy: pol, block_rows, strategy, lambda: 1e-6 }
}

fn fleet(pol: ParallelPolicy, strategy: SolveStrategy, block_rows: usize) -> FleetTrainer {
    let mut f = FleetTrainer::with_policy(pol);
    f.strategy = strategy;
    f.block_rows = block_rows;
    f
}

fn train_req(tenant: &str, arch: Arch, m: usize, seed: u64, data: Windowed) -> FleetRequest {
    FleetRequest::Train { tenant: tenant.to_string(), arch, m, seed, data }
}

fn beta_of(f: &FleetTrainer, tenant: &str) -> Vec<f64> {
    f.model(tenant).expect("cached model").beta.clone()
}

fn assert_all_trained(out: &[(String, FleetOutcome)]) {
    for (tenant, o) in out {
        assert!(
            matches!(o, FleetOutcome::Trained { .. }),
            "tenant {tenant} did not train: {o:?}"
        );
    }
}

/// Rows of two same-shape window sets, concatenated — H rows depend only
/// on their own window row, so this is "all rows seen" for the RLS test.
fn concat_windows(a: &Windowed, b: &Windowed) -> Windowed {
    assert_eq!((a.s, a.q), (b.s, b.q));
    Windowed {
        n: a.n + b.n,
        s: a.s,
        q: a.q,
        x: [a.x.clone(), b.x.clone()].concat(),
        y: [a.y.clone(), b.y.clone()].concat(),
        yhist: [a.yhist.clone(), b.yhist.clone()].concat(),
    }
}

/// Tentpole conformance: a three-tenant group (ragged lengths, multiple
/// blocks each) is bit-identical to three solo runs — every arch, both
/// wires, 1/2/4/8 workers, the Gram fleet default.
#[test]
fn grouped_beta_is_bitwise_solo_every_arch_wire_worker() {
    for &arch in ALL_ARCHS.iter() {
        for precision in [Precision::F64, Precision::MixedF32] {
            for workers in [1usize, 2, 4, 8] {
                let pol = policy(workers, precision);
                let st = solo(pol, SolveStrategy::Gram, 64);
                let datas =
                    [windows(150, 3, 1), windows(200, 3, 2), windows(170, 3, 3)];
                let mut fl = fleet(pol, SolveStrategy::Gram, 64);
                for (i, d) in datas.iter().enumerate() {
                    fl.submit(train_req(
                        &format!("t{i}"),
                        arch,
                        10,
                        40 + i as u64,
                        d.clone(),
                    ))
                    .unwrap();
                }
                let out = fl.drain();
                assert_all_trained(&out);
                for (i, d) in datas.iter().enumerate() {
                    let (model, _) = st.train(arch, d, 10, 40 + i as u64).unwrap();
                    assert_eq!(
                        beta_of(&fl, &format!("t{i}")),
                        model.beta,
                        "β drifted from solo: arch={arch:?} precision={precision:?} \
                         workers={workers} tenant=t{i}"
                    );
                }
            }
        }
    }
}

/// The factorization strategies share `solve_blocks` with the solo
/// trainer — pin that the grouped stream feeding it stays bit-identical.
#[test]
fn grouped_beta_is_bitwise_solo_factorization_strategies() {
    for strategy in [SolveStrategy::Tsqr, SolveStrategy::DirectQr] {
        for precision in [Precision::F64, Precision::MixedF32] {
            for &arch in &[Arch::Elman, Arch::Fc, Arch::Narmax] {
                let pol = policy(4, precision);
                let st = solo(pol, strategy, 64);
                let datas = [windows(180, 3, 4), windows(140, 3, 5)];
                let mut fl = fleet(pol, strategy, 64);
                for (i, d) in datas.iter().enumerate() {
                    fl.submit(train_req(
                        &format!("t{i}"),
                        arch,
                        9,
                        70 + i as u64,
                        d.clone(),
                    ))
                    .unwrap();
                }
                let out = fl.drain();
                assert_all_trained(&out);
                for (i, d) in datas.iter().enumerate() {
                    let (model, _) = st.train(arch, d, 9, 70 + i as u64).unwrap();
                    assert_eq!(
                        beta_of(&fl, &format!("t{i}")),
                        model.beta,
                        "β drifted from solo: strategy={strategy:?} arch={arch:?} \
                         precision={precision:?} tenant=t{i}"
                    );
                }
            }
        }
    }
}

/// Ragged groups: 1, 2, and 17 tenants of differing lengths all match
/// their solo runs bitwise — group size never leaks into any member's β.
#[test]
fn ragged_group_sizes_match_solo() {
    let pol = policy(4, Precision::F64);
    let st = solo(pol, SolveStrategy::Gram, 64);
    for &count in &[1usize, 2, 17] {
        let datas: Vec<Windowed> =
            (0..count).map(|i| windows(80 + 17 * i, 2, i as u64)).collect();
        let mut fl = fleet(pol, SolveStrategy::Gram, 64);
        for (i, d) in datas.iter().enumerate() {
            fl.submit(train_req(&format!("t{i}"), Arch::Jordan, 8, 100 + i as u64, d.clone()))
                .unwrap();
        }
        let out = fl.drain();
        assert_all_trained(&out);
        for (i, d) in datas.iter().enumerate() {
            let (model, _) = st.train(Arch::Jordan, d, 8, 100 + i as u64).unwrap();
            assert_eq!(
                beta_of(&fl, &format!("t{i}")),
                model.beta,
                "group of {count}: tenant t{i} drifted from solo"
            );
        }
    }
}

/// Mixed-shape batches: tenants landing in different groups get the same
/// β (and outcome order follows submission) no matter how the queue was
/// interleaved.
#[test]
fn mixed_shape_submission_order_invariant() {
    let pol = policy(4, Precision::F64);
    // (tenant, arch, m, q, seed, n): three distinct group keys, two
    // members each
    let specs: Vec<(String, Arch, usize, usize, u64, usize)> = vec![
        ("a0".into(), Arch::Elman, 8, 2, 1, 120),
        ("b0".into(), Arch::Gru, 6, 3, 2, 140),
        ("c0".into(), Arch::Elman, 8, 3, 3, 130),
        ("a1".into(), Arch::Elman, 8, 2, 4, 160),
        ("b1".into(), Arch::Gru, 6, 3, 5, 110),
        ("c1".into(), Arch::Elman, 8, 3, 6, 150),
    ];
    let run = |order: &[usize]| -> Vec<(String, Vec<f64>)> {
        let mut fl = fleet(pol, SolveStrategy::Gram, 64);
        for &i in order {
            let (t, arch, m, q, seed, n) = &specs[i];
            fl.submit(train_req(t, *arch, *m, *seed, windows(*n, *q, *seed)))
                .unwrap();
        }
        let out = fl.drain();
        assert_all_trained(&out);
        // submission order is preserved in the outcome list
        let submitted: Vec<&str> =
            order.iter().map(|&i| specs[i].0.as_str()).collect();
        let returned: Vec<&str> = out.iter().map(|(t, _)| t.as_str()).collect();
        assert_eq!(submitted, returned, "outcomes must follow submission order");
        let mut betas: Vec<(String, Vec<f64>)> = specs
            .iter()
            .map(|(t, ..)| (t.clone(), beta_of(&fl, t)))
            .collect();
        betas.sort_by(|a, b| a.0.cmp(&b.0));
        betas
    };
    let forward = run(&[0, 1, 2, 3, 4, 5]);
    let shuffled = run(&[5, 2, 4, 0, 3, 1]);
    assert_eq!(forward, shuffled, "submission order changed some tenant's β");
}

/// Warm updates: after a cache-hit RLS update, the tenant's β equals
/// batch ridge over *all* rows seen (training rows + update rows) at the
/// training λ — the `elm::online` seeding invariant, end to end.
#[test]
fn rls_update_matches_batch_ridge_over_all_rows() {
    let pol = policy(2, Precision::F64);
    let train_d = windows(160, 3, 5);
    let upd_d = windows(48, 3, 9);
    let m = 8usize;
    let mut fl = fleet(pol, SolveStrategy::Gram, 64);
    fl.submit(train_req("hot", Arch::Elman, m, 11, train_d.clone())).unwrap();
    assert_all_trained(&fl.drain());
    fl.submit(FleetRequest::Update { tenant: "hot".into(), data: upd_d.clone() })
        .unwrap();
    let out = fl.drain();
    match &out[0].1 {
        FleetOutcome::Updated { outcome, rows_seen } => {
            assert_eq!(
                *outcome,
                opt_pr_elm::elm::RlsOutcome::Applied,
                "clean update must apply"
            );
            assert_eq!(*rows_seen, train_d.n + upd_d.n);
        }
        other => panic!("expected Updated, got {other:?}"),
    }
    // reference: batch ridge over the concatenated rows at λ = 1e-6
    let params = fl.model("hot").unwrap().params.clone();
    let all = concat_windows(&train_d, &upd_d);
    let h = hidden_matrix(&params, &all, None);
    let mut g = h.gram_with(ParallelPolicy::sequential());
    for i in 0..m {
        g[(i, i)] += 1e-6;
    }
    let y: Vec<f64> = all.y.iter().map(|&v| v as f64).collect();
    let c = h.t_matvec(&y);
    let beta_ref = cholesky_solve(&g, &c).unwrap();
    let beta = beta_of(&fl, "hot");
    for (j, (&b, &r)) in beta.iter().zip(&beta_ref).enumerate() {
        let tol = 1e-5 * r.abs().max(1.0);
        assert!(
            (b - r).abs() <= tol,
            "β[{j}] = {b} vs batch ridge {r} (diff {})",
            (b - r).abs()
        );
    }
}

/// Grouped predict: the packed group-GEMM path agrees with the solo
/// block-matvec predict for every cached tenant (β itself is bitwise solo
/// by the training contract; the GEMM may differ from matvec only within
/// float round-off).
#[test]
fn grouped_predict_matches_solo_predict() {
    let pol = policy(4, Precision::F64);
    let st = solo(pol, SolveStrategy::Gram, 64);
    let tenants: Vec<(&str, Arch, usize, usize, u64)> = vec![
        ("p0", Arch::Elman, 8, 2, 21),
        ("p1", Arch::Fc, 6, 3, 22),
        ("p2", Arch::Gru, 7, 2, 23),
        ("p3", Arch::Narmax, 8, 3, 24),
    ];
    let mut fl = fleet(pol, SolveStrategy::Gram, 64);
    for &(t, arch, m, q, seed) in &tenants {
        fl.submit(train_req(t, arch, m, seed, windows(150, q, seed))).unwrap();
    }
    assert_all_trained(&fl.drain());
    for &(t, _, _, q, seed) in &tenants {
        fl.submit(FleetRequest::Predict {
            tenant: t.to_string(),
            data: windows(90, q, seed + 50),
        })
        .unwrap();
    }
    let out = fl.drain();
    for (&(t, _, _, q, seed), (tenant, o)) in tenants.iter().zip(&out) {
        assert_eq!(t, tenant);
        let yhat = match o {
            FleetOutcome::Predicted { yhat } => yhat,
            other => panic!("expected Predicted for {t}, got {other:?}"),
        };
        let model = fl.model(t).unwrap().clone();
        let reference =
            st.predict(&model, &windows(90, q, seed + 50)).unwrap();
        assert_eq!(yhat.len(), reference.len());
        for (i, (&a, &b)) in yhat.iter().zip(&reference).enumerate() {
            let tol = 1e-10 * b.abs().max(1.0);
            assert!(
                (a - b).abs() <= tol,
                "{t} yhat[{i}] = {a} vs solo {b}"
            );
        }
    }
}

/// LRU cache under capacity pressure: identical submission sequences at
/// 1 and 4 workers leave the identical cached-tenant set, bitwise-equal
/// survivor βs, and outcomes in submission order — eviction order never
/// depends on worker count or map iteration order (the cache is a
/// `BTreeMap`, so ties on the LRU clock evict the smallest tenant id).
#[test]
fn lru_eviction_is_submission_deterministic_across_workers() {
    let tenants: Vec<String> = (0..6).map(|i| format!("t{i}")).collect();
    type RunOut = (Vec<String>, Vec<String>, Vec<(String, Vec<f64>)>);
    let run = |workers: usize| -> RunOut {
        let mut fl = fleet(policy(workers, Precision::F64), SolveStrategy::Gram, 64);
        fl.cache_capacity = 3;
        // first wave: four trains into a 3-slot cache (one eviction)
        for (i, t) in tenants.iter().take(4).enumerate() {
            fl.submit(train_req(
                t,
                Arch::Elman,
                8,
                30 + i as u64,
                windows(120 + 10 * i, 2, i as u64),
            ))
            .unwrap();
        }
        let mut order: Vec<String> =
            fl.drain().into_iter().map(|(t, _)| t).collect();
        // touch t1 so it outlives the second wave's evictions
        fl.submit(FleetRequest::Predict {
            tenant: tenants[1].clone(),
            data: windows(40, 2, 9),
        })
        .unwrap();
        fl.drain();
        // second wave: two more trains force two further evictions
        for (i, t) in tenants.iter().enumerate().skip(4) {
            fl.submit(train_req(
                t,
                Arch::Elman,
                8,
                30 + i as u64,
                windows(120 + 10 * i, 2, i as u64),
            ))
            .unwrap();
        }
        order.extend(fl.drain().into_iter().map(|(t, _)| t));
        assert_eq!(fl.cached(), 3, "cache must sit exactly at capacity");
        let survivors: Vec<String> =
            tenants.iter().filter(|t| fl.has_model(t)).cloned().collect();
        let betas: Vec<(String, Vec<f64>)> =
            survivors.iter().map(|t| (t.clone(), beta_of(&fl, t))).collect();
        (order, survivors, betas)
    };
    let (o1, s1, b1) = run(1);
    let (o4, s4, b4) = run(4);
    assert_eq!(o1, o4, "outcome order must not depend on worker count");
    assert_eq!(
        s1,
        vec!["t1".to_string(), "t4".into(), "t5".into()],
        "survivors must be exactly the three most recently used tenants"
    );
    assert_eq!(s1, s4, "cached-tenant set must not depend on worker count");
    assert_eq!(b1, b4, "survivor βs must be bitwise identical across workers");
}

/// Degenerate sweep: empty drain, duplicate tenant id, an underdetermined
/// tenant failing typed inside a healthy group (whose group-mate stays
/// bitwise solo), and cache misses after eviction.
#[test]
fn degenerate_fleet_cases() {
    let pol = policy(2, Precision::F64);

    // empty fleet drains to an empty outcome list
    let mut fl = fleet(pol, SolveStrategy::Gram, 64);
    assert!(fl.drain().is_empty());

    // duplicate tenant id rejected at submit with a typed error
    fl.submit(train_req("dup", Arch::Elman, 8, 1, windows(100, 2, 1))).unwrap();
    let err = fl
        .submit(train_req("dup", Arch::Elman, 8, 2, windows(100, 2, 2)))
        .unwrap_err();
    assert_eq!(
        as_solve_error(&err).map(SolveError::class),
        Some("duplicate-tenant")
    );
    fl.drain();

    // a tenant with fewer rows than M fails typed (underdetermined, rung
    // recorded as failed) while its same-group mate trains bitwise solo
    let big_d = windows(200, 2, 7);
    let mut fl = fleet(pol, SolveStrategy::Gram, 64);
    fl.submit(train_req("big", Arch::Elman, 12, 3, big_d.clone())).unwrap();
    fl.submit(train_req("tiny", Arch::Elman, 12, 4, windows(6, 2, 8))).unwrap();
    let out = fl.drain();
    match &out[1].1 {
        FleetOutcome::Failed { error, report } => {
            assert_eq!(error.class(), "underdetermined", "{error}");
            assert_eq!(report.rung, DegradationRung::Failed);
        }
        other => panic!("expected tiny to fail, got {other:?}"),
    }
    assert!(matches!(out[0].1, FleetOutcome::Trained { .. }));
    let st = solo(pol, SolveStrategy::Gram, 64);
    let (model, _) = st.train(Arch::Elman, &big_d, 12, 3).unwrap();
    assert_eq!(beta_of(&fl, "big"), model.beta, "group-mate must stay bitwise solo");
    assert!(!fl.has_model("tiny"), "failed trains must not be cached");

    // predict/update on an unknown (never trained or evicted) tenant is
    // screened at submit time since ISSUE 10 — the typed error arrives
    // before the request ever occupies a queue slot
    let err = fl
        .submit(FleetRequest::Predict { tenant: "ghost".into(), data: windows(30, 2, 1) })
        .unwrap_err();
    assert_eq!(
        as_solve_error(&err).map(SolveError::class),
        Some("unknown-tenant")
    );
    let err = fl
        .submit(FleetRequest::Update { tenant: "ghost".into(), data: windows(30, 2, 1) })
        .unwrap_err();
    assert_eq!(
        as_solve_error(&err).map(SolveError::class),
        Some("unknown-tenant")
    );
    assert!(fl.drain().is_empty(), "screened requests never reach the queue");
}
