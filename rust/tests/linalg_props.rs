//! Property tests over the linalg substrate: QR/TSQR/Cholesky invariants
//! on randomized shapes and conditioning.

use opt_pr_elm::linalg::{
    cholesky_solve, householder_qr, lstsq_qr, lstsq_ridge, solve_upper_triangular, Matrix,
    TsqrAccumulator,
};
use opt_pr_elm::testing::prop;
use opt_pr_elm::util::rng::Rng;

fn random_matrix(g: &mut prop::Gen, rows: usize, cols: usize) -> Matrix {
    let mut rng = Rng::new(g.u64());
    Matrix::random(rows, cols, &mut rng)
}

#[test]
fn qr_reconstruction_property() {
    prop::check(60, |g| {
        let n = g.size(1, 12);
        let m = n + g.size(0, 40);
        let a = random_matrix(g, m, n);
        let f = householder_qr(&a).map_err(|e| e.to_string())?;
        let qr = f.q().matmul(&f.r());
        prop::assert_close(qr.max_abs_diff(&a), 0.0, 1e-9, &format!("A=QR {m}x{n}"))
    });
}

#[test]
fn qr_orthonormality_property() {
    prop::check(40, |g| {
        let n = g.size(1, 10);
        let m = n + g.size(0, 30);
        let a = random_matrix(g, m, n);
        let q = householder_qr(&a).map_err(|e| e.to_string())?.q();
        let qtq = q.transpose().matmul(&q);
        prop::assert_close(qtq.max_abs_diff(&Matrix::identity(n)), 0.0, 1e-9, "QtQ=I")
    });
}

#[test]
fn lstsq_residual_orthogonality_property() {
    prop::check(40, |g| {
        let n = g.size(1, 8);
        let m = n + 2 + g.size(0, 50);
        let a = random_matrix(g, m, n);
        let b = g.normals(m);
        let x = lstsq_qr(&a, &b).map_err(|e| e.to_string())?;
        let ax = a.matvec(&x);
        let resid: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
        let at_r = a.t_matvec(&resid);
        let worst = at_r.iter().fold(0.0f64, |acc, v| acc.max(v.abs()));
        prop::assert_close(worst, 0.0, 1e-7, "Aᵀr = 0")
    });
}

#[test]
fn tsqr_equals_direct_qr_property() {
    prop::check(25, |g| {
        let n = g.size(1, 8);
        let rows = n + 4 + g.size(0, 120);
        let a = random_matrix(g, rows, n);
        let b = g.normals(rows);
        let direct = lstsq_qr(&a, &b).map_err(|e| e.to_string())?;
        let block = g.size(1, 40);
        let mut acc = TsqrAccumulator::new(n);
        let mut i = 0;
        while i < rows {
            let hi = (i + block).min(rows);
            acc.push_block(a.submatrix(i, hi, 0, n), &b[i..hi])
                .map_err(|e| e.to_string())?;
            i = hi;
        }
        let beta = acc.solve().map_err(|e| e.to_string())?;
        let worst = beta
            .iter()
            .zip(&direct)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        prop::assert_close(worst, 0.0, 1e-7, &format!("tsqr block={block}"))
    });
}

#[test]
fn cholesky_solve_property() {
    prop::check(40, |g| {
        let n = g.size(1, 10);
        let a = random_matrix(g, n + 3, n);
        let mut spd = a.gram();
        for i in 0..n {
            spd[(i, i)] += 1.0;
        }
        let x_true = g.normals(n);
        let b = spd.matvec(&x_true);
        let x = cholesky_solve(&spd, &b).map_err(|e| e.to_string())?;
        let worst = x
            .iter()
            .zip(&x_true)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0f64, f64::max);
        prop::assert_close(worst, 0.0, 1e-6, "chol solve")
    });
}

#[test]
fn ridge_shrinks_toward_zero_property() {
    // ‖β(λ_big)‖ <= ‖β(λ_small)‖ : monotone shrinkage
    prop::check(25, |g| {
        let n = g.size(2, 8);
        let m = n + 5 + g.size(0, 40);
        let a = random_matrix(g, m, n);
        let b = g.normals(m);
        let norm = |v: &[f64]| v.iter().map(|x| x * x).sum::<f64>().sqrt();
        let small = lstsq_ridge(&a, &b, 1e-10).map_err(|e| e.to_string())?;
        let big = lstsq_ridge(&a, &b, 10.0).map_err(|e| e.to_string())?;
        prop::assert_prop(
            norm(&big) <= norm(&small) + 1e-9,
            format!("‖β(10)‖={} > ‖β(1e-10)‖={}", norm(&big), norm(&small)),
        )
    });
}

#[test]
fn upper_solve_inverts_property() {
    prop::check(40, |g| {
        let n = g.size(1, 10);
        let a = random_matrix(g, n, n);
        let mut r = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r[(i, j)] = a[(i, j)] + if i == j { 2.0 } else { 0.0 };
            }
        }
        let x = g.normals(n);
        let b = r.matvec(&x);
        let got = solve_upper_triangular(&r, &b).map_err(|e| e.to_string())?;
        let worst = got
            .iter()
            .zip(&x)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0f64, f64::max);
        prop::assert_close(worst, 0.0, 1e-8, "back substitution")
    });
}
