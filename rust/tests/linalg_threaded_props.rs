//! Property tests pinning the threaded linalg paths to their PR-1
//! single-threaded references on random rectangular and degenerate shapes:
//!
//! * `matmul_with` ≡ `matmul` **bitwise** at any worker count (output row
//!   tiles are disjoint; each element is produced by the identical
//!   kernel),
//! * `gram_with` bit-invariant across worker counts and tolerance-pinned
//!   to the explicit AᵀA (the chunked fold reassociates),
//! * the panel-resident blocked `apply_qt` tolerance-pinned to the
//!   column-at-a-time reference on the *same factors*,
//! * NaN/inf propagation preserved by every threaded path (no zero-skip
//!   branches anywhere in the substrate).

use opt_pr_elm::linalg::{
    householder_qr, lstsq_qr, lstsq_qr_with, Matrix, ParallelPolicy,
};
use opt_pr_elm::testing::prop;
use opt_pr_elm::util::rng::Rng;

fn random_matrix(g: &mut prop::Gen, rows: usize, cols: usize) -> Matrix {
    let mut rng = Rng::new(g.u64());
    Matrix::random(rows, cols, &mut rng)
}

#[test]
fn threaded_matmul_bit_identical_property() {
    prop::check(40, |g| {
        // degenerate shapes on a rotating schedule: 0×n, 1×1, tall-skinny
        let (m, k, n) = match g.case % 5 {
            0 => (0, 1 + g.size(0, 8), 1 + g.size(0, 8)),
            1 => (1, 1, 1),
            2 => (200 + g.size(0, 600), 1 + g.size(0, 4), 1 + g.size(0, 12)),
            _ => (1 + g.size(0, 180), 1 + g.size(0, 90), 1 + g.size(0, 90)),
        };
        let a = random_matrix(g, m, k);
        let b = random_matrix(g, k, n);
        let seq = a.matmul(&b);
        for workers in [2usize, 4, 8] {
            let par = a.matmul_with(&b, ParallelPolicy::with_workers(workers));
            prop::assert_prop(
                par == seq,
                format!("matmul {m}x{k}x{n} bits differ at workers={workers}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn threaded_gram_worker_invariant_property() {
    prop::check(25, |g| {
        // tall enough to span several 512-row chunks in most cases
        let rows = match g.case % 4 {
            0 => g.size(0, 3), // degenerate: 0..3 rows
            _ => 1 + g.size(0, 1500),
        };
        let cols = 1 + g.size(0, 24);
        let a = random_matrix(g, rows, cols);
        let base = a.gram_with(ParallelPolicy::sequential());
        for workers in [2usize, 4, 8] {
            let gthr = a.gram_with(ParallelPolicy::with_workers(workers));
            prop::assert_prop(
                gthr == base,
                format!("gram {rows}x{cols} bits differ at workers={workers}"),
            )?;
        }
        // tolerance-pinned to the explicit product (the fold reassociates)
        let explicit = a.transpose().matmul(&a);
        prop::assert_close(
            base.max_abs_diff(&explicit),
            0.0,
            1e-9 * (rows.max(1) as f64),
            &format!("gram {rows}x{cols} vs explicit AᵀA"),
        )
    });
}

#[test]
fn threaded_matmul_propagates_non_finite() {
    // 0 × ∞ must surface as NaN through the threaded path too (no
    // zero-skip branch): plant an inf in A and zeros in B, tall enough
    // that several row tiles are live
    let rows = 300;
    let mut a = Matrix::zeros(rows, 3);
    for i in 0..rows {
        a[(i, 0)] = 1.0;
    }
    a[(200, 1)] = f64::INFINITY;
    let b = Matrix::from_vec(3, 2, vec![1.0, 2.0, 0.0, 1.0, 3.0, -1.0]);
    let c = a.matmul_with(&b, ParallelPolicy::with_workers(4));
    assert!(c[(200, 0)].is_nan(), "inf*0 dropped: {}", c[(200, 0)]);
    assert!(c[(0, 0)].is_finite());
    // matches the sequential result bit-for-bit elsewhere and NaN-for-NaN
    let seq = a.matmul(&b);
    for i in 0..rows {
        for j in 0..2 {
            let (x, y) = (c[(i, j)], seq[(i, j)]);
            assert!(x == y || (x.is_nan() && y.is_nan()), "({i},{j}): {x} vs {y}");
        }
    }
}

#[test]
fn threaded_gram_propagates_non_finite() {
    // rows > one chunk so the partial fold carries the NaN through
    let rows = 700;
    let mut a = Matrix::zeros(rows, 2);
    for i in 0..rows {
        a[(i, 0)] = 0.5;
    }
    a[(600, 0)] = 0.0;
    a[(600, 1)] = f64::INFINITY; // row 600 = [0, inf]: G[0][1] sees 0 * inf = NaN
    let g = a.gram_with(ParallelPolicy::with_workers(4));
    assert!(
        g.data().iter().any(|v| v.is_nan()),
        "gram dropped the 0*inf NaN"
    );
}

#[test]
fn blocked_apply_qt_matches_reference_property() {
    // same factors, both application paths, random shapes spanning one to
    // several PANEL-wide panels
    prop::check(30, |g| {
        let n = 1 + g.size(0, 80);
        let m = n + g.size(0, 150);
        let a = random_matrix(g, m, n);
        let f = householder_qr(&a).map_err(|e| e.to_string())?;
        let b = g.normals(m);
        let mut panel = b.clone();
        let mut column = b;
        f.apply_qt(&mut panel);
        f.apply_qt_reference(&mut column);
        let worst = panel
            .iter()
            .zip(&column)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        prop::assert_close(worst, 0.0, 1e-9, &format!("Qᵀb panel vs column {m}x{n}"))
    });
}

#[test]
fn blocked_apply_qt_degenerate_columns_property() {
    // zero and duplicated columns exercise the beta = 0 (H = I) rows of T
    // and the rank-deficient reflectors
    prop::check(20, |g| {
        let base_n = 1 + g.size(0, 20);
        let n = base_n * 2;
        let m = n + 4 + g.size(0, 80);
        let base = random_matrix(g, m, base_n);
        let mut a = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..base_n {
                a[(i, j)] = base[(i, j)];
                a[(i, base_n + j)] = if g.case % 3 == 0 { 0.0 } else { base[(i, j)] };
            }
        }
        let f = householder_qr(&a).map_err(|e| e.to_string())?;
        let b = g.normals(m);
        let mut panel = b.clone();
        let mut column = b;
        f.apply_qt(&mut panel);
        f.apply_qt_reference(&mut column);
        let worst = panel
            .iter()
            .zip(&column)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        prop::assert_close(worst, 0.0, 1e-9, &format!("degenerate Qᵀb {m}x{n}"))
    });
}

#[test]
fn threaded_lstsq_qr_bit_identical_property() {
    // end to end through the solver: threaded β ≡ sequential β bitwise
    prop::check(15, |g| {
        let n = 1 + g.size(0, 40);
        let rows = n + 2 + g.size(0, 400);
        let a = random_matrix(g, rows, n);
        let b = g.normals(rows);
        let base = lstsq_qr(&a, &b).map_err(|e| e.to_string())?;
        for workers in [2usize, 4, 8] {
            let x = lstsq_qr_with(&a, &b, ParallelPolicy::with_workers(workers))
                .map_err(|e| e.to_string())?;
            prop::assert_prop(
                x == base,
                format!("lstsq_qr {rows}x{n} β bits differ at workers={workers}"),
            )?;
        }
        Ok(())
    });
}
