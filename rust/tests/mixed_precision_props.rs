//! Conformance suite for the accumulate-widen (f32 wire / f64 accumulate)
//! substrate, pinning the documented kernel contract of
//! `linalg::matrix32`:
//!
//! * `matmul_widen` / `gram_widen` are **bit-identical across worker
//!   counts** (same fixed tile schedule as the f64 kernels),
//! * on f32-born operands they are **bit-identical to the f64 kernels**
//!   (every f32×f32 product is exact in f64),
//! * on f64-rounded operands the element-wise drift versus the f64
//!   reference obeys the documented ulp bound
//!   `|Δ[i,j]| ≤ 2⁻²³·(|A|·|B|)[i,j]` (one storage rounding per operand,
//!   f64 accumulator — no length-dependent error growth),
//! * the GEMM-lifted FC `h_block` matches its scalar reference and
//!   `h_row` (property over random shapes),
//! * the mixed-precision BPTT forward matches the f64 wire per its
//!   contract (FC/GRU bitwise; LSTM bounded).

use opt_pr_elm::bptt::init::{init_params, BpttArch};
use opt_pr_elm::bptt::{forward_cpu_with, BpttModel};
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::elm::arch::{fc, SampleBlock};
use opt_pr_elm::elm::{Arch, ElmParams};
use opt_pr_elm::linalg::{Matrix, MatrixF32, ParallelPolicy, Precision};
use opt_pr_elm::testing::prop;
use opt_pr_elm::util::rng::Rng;

fn random_matrix(g: &mut prop::Gen, rows: usize, cols: usize) -> Matrix {
    let mut rng = Rng::new(g.u64());
    Matrix::random(rows, cols, &mut rng)
}

/// |A| (element-wise absolute value).
fn abs_matrix(a: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(a.rows, a.cols);
    for (o, v) in out.data_mut().iter_mut().zip(a.data()) {
        *o = v.abs();
    }
    out
}

#[test]
fn widen_matmul_worker_invariant_property() {
    prop::check(30, |g| {
        let (m, k, n) = match g.case % 4 {
            0 => (0, 1 + g.size(0, 8), 1 + g.size(0, 8)),
            1 => (1, 1, 1),
            2 => (200 + g.size(0, 400), 1 + g.size(0, 4), 1 + g.size(0, 12)),
            _ => (1 + g.size(0, 180), 1 + g.size(0, 90), 1 + g.size(0, 90)),
        };
        let a = MatrixF32::from_matrix(&random_matrix(g, m, k));
        let b = MatrixF32::from_matrix(&random_matrix(g, k, n));
        let seq = a.matmul_widen(&b, ParallelPolicy::sequential());
        for workers in [2usize, 4, 8] {
            let par = a.matmul_widen(&b, ParallelPolicy::with_workers(workers));
            prop::assert_prop(
                par == seq,
                format!("matmul_widen {m}x{k}x{n} bits differ at workers={workers}"),
            )?;
        }
        // and identical to the f64 tiled GEMM on the (exactly) widened
        // operands — 0 ulp kernel drift
        let f64ref = a.to_f64().matmul(&b.to_f64());
        prop::assert_prop(
            seq == f64ref,
            format!("matmul_widen {m}x{k}x{n} != f64 GEMM on widened operands"),
        )
    });
}

#[test]
fn widen_matmul_ulp_bound_vs_f64_reference_property() {
    // f64-born operands: the only error is the f32 storage rounding,
    // bounded element-wise by 2^-23 * (|A|·|B|)[i,j] whatever the depth k
    prop::check(25, |g| {
        let m = 1 + g.size(0, 60);
        let k = 1 + g.size(0, 300);
        let n = 1 + g.size(0, 60);
        let a = random_matrix(g, m, k);
        let b = random_matrix(g, k, n);
        let widen = MatrixF32::from_matrix(&a)
            .matmul_widen(&MatrixF32::from_matrix(&b), ParallelPolicy::sequential());
        let reference = a.matmul(&b);
        let envelope = abs_matrix(&a).matmul(&abs_matrix(&b));
        // documented bound is 2^-23 · (|A|·|B|); 5% headroom covers the
        // strictly-accounted 2^-48 second-order term and the f64
        // accumulation difference between the two sums
        let bound = 1.05 * (2.0f64).powi(-23);
        for i in 0..m {
            for j in 0..n {
                let drift = (widen[(i, j)] - reference[(i, j)]).abs();
                prop::assert_prop(
                    drift <= bound * envelope[(i, j)] + 1e-300,
                    format!(
                        "({i},{j}) of {m}x{k}x{n}: drift {drift:e} exceeds \
                         2^-23 * {:e}",
                        envelope[(i, j)]
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn widen_gram_worker_invariant_and_ulp_bounded_property() {
    prop::check(20, |g| {
        let rows = match g.case % 4 {
            0 => g.size(0, 3),
            _ => 1 + g.size(0, 1400),
        };
        let cols = 1 + g.size(0, 20);
        let a = random_matrix(g, rows, cols);
        let a32 = MatrixF32::from_matrix(&a);
        let base = a32.gram_widen(ParallelPolicy::sequential());
        for workers in [2usize, 4, 8] {
            let gthr = a32.gram_widen(ParallelPolicy::with_workers(workers));
            prop::assert_prop(
                gthr == base,
                format!("gram_widen {rows}x{cols} bits differ at workers={workers}"),
            )?;
        }
        // bit-identical to the f64 gram of the widened operand
        prop::assert_prop(
            base == a32.to_f64().gram_with(ParallelPolicy::sequential()),
            format!("gram_widen {rows}x{cols} != f64 gram on widened operand"),
        )?;
        // ulp envelope vs the f64 reference on the unrounded operand
        let reference = a.gram_with(ParallelPolicy::sequential());
        let aabs = abs_matrix(&a);
        let envelope = aabs.transpose().matmul(&aabs);
        let bound = (2.0f64).powi(-23);
        for x in 0..cols {
            for y in 0..cols {
                let drift = (base[(x, y)] - reference[(x, y)]).abs();
                // gram_with reassociates vs the widen fold only through
                // identical chunk schedules, so the envelope still holds
                // with a small slack for the f64 fold's own rounding
                prop::assert_prop(
                    drift <= bound * envelope[(x, y)] + 1e-9 * envelope[(x, y)] + 1e-300,
                    format!(
                        "({x},{y}) of gram {rows}x{cols}: drift {drift:e} vs \
                         envelope {:e}",
                        envelope[(x, y)]
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn fc_h_block_matches_h_row_property() {
    // dedicated FC coverage at larger (q, m) than the all-arch sweep: the
    // GEMM-lifted recurrence vs the scalar reference and the one-sample
    // recurrence
    prop::check(20, |g| {
        let s = 1 + g.size(0, 2);
        let q = 1 + g.size(0, 11);
        let m = 1 + g.size(0, 17);
        let rows = 1 + g.size(0, 50);
        let x = g.vec_f32(rows * s * q, -1.0, 1.0);
        let yh = vec![0f32; rows * q];
        let eh = vec![0f32; rows * q];
        let p = ElmParams::init(Arch::Fc, s, q, m, g.u64());
        let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };
        let batched = fc::h_block(&p, &blk);
        let reference = fc::h_block_reference(&p, &blk);
        prop::assert_close(
            batched.max_abs_diff(&reference),
            0.0,
            1e-5,
            &format!("fc h_block vs reference ({s},{q},{m}) rows={rows}"),
        )?;
        let mut out = vec![0f32; m];
        for i in 0..rows {
            fc::h_row(&p, &x[i * s * q..(i + 1) * s * q], &mut out);
            for j in 0..m {
                prop::assert_close(
                    batched[(i, j)],
                    out[j] as f64,
                    1e-5,
                    &format!("fc h_block vs h_row row {i} col {j}"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn mixed_wire_bptt_forward_contract() {
    let mut rng = Rng::new(3);
    let series: Vec<f64> = (0..160).map(|_| rng.range(0.0, 1.0)).collect();
    let w = Windowed::from_series(&series, 6).unwrap();
    // FC and GRU: hidden state exactly f32-representable (all-f32 cell
    // math) → identical bits on either wire
    for arch in [BpttArch::Fc, BpttArch::Gru] {
        let mdl = BpttModel {
            arch,
            s: w.s,
            q: w.q,
            m: 8,
            params: init_params(arch, w.s, 8, 5),
        };
        assert_eq!(
            forward_cpu_with(&mdl, &w, Precision::MixedF32),
            forward_cpu_with(&mdl, &w, Precision::F64),
            "{}: mixed wire changed bits",
            arch.name()
        );
    }
    // LSTM: f64 cell state → one f32 rounding of h per step, bounded drift
    let mdl = BpttModel {
        arch: BpttArch::Lstm,
        s: w.s,
        q: w.q,
        m: 8,
        params: init_params(BpttArch::Lstm, w.s, 8, 6),
    };
    let f64p = forward_cpu_with(&mdl, &w, Precision::F64);
    let mixed = forward_cpu_with(&mdl, &w, Precision::MixedF32);
    let worst = f64p
        .iter()
        .zip(&mixed)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(worst < 1e-4, "lstm: mixed-wire drift {worst}");
}
