//! End-to-end coordinator test: the parallel trainer (PJRT artifacts +
//! streaming Gram accumulation) must reproduce the sequential S-R-ELM
//! baseline — same β (up to f32 accumulation) and same test RMSE.

// Every test below is `#[ignore]`d by default: it needs the real PJRT
// runtime (`pjrt` feature + AOT artifacts from python/compile), which the
// offline build replaces with the erroring xla shim. The in-test
// `artifacts_ready()` guard is kept so `--ignored` runs still self-skip
// gracefully when artifacts are missing. Tracking: ISSUE 2 satellite
// "triage the failing seed tests".
use opt_pr_elm::coordinator::PrElmTrainer;
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::elm::{Arch, SrElmModel, TrainOptions, ALL_ARCHS};
use opt_pr_elm::runtime::default_artifacts_dir;
use opt_pr_elm::util::rng::Rng;

fn artifacts_ready() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

/// Learnable AR series in [0, 1] with enough noise + frequency mix that
/// the random-feature matrix H is decently conditioned (β comparisons
/// between f32 and f64 accumulation are meaningless at cond(HᵀH) ≫ 1e8).
fn toy_series(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut y = vec![0.3f64, 0.45];
    for t in 2..n {
        let v = 0.45 * y[t - 1] + 0.2 * y[t - 2]
            + 0.15 * (t as f64 * 0.15).sin()
            + 0.1 * (t as f64 * 0.71).cos()
            + 0.12 * rng.normal();
        y.push(v);
    }
    let lo = y.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = y.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    y.iter().map(|v| (v - lo) / (hi - lo)).collect()
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn parallel_matches_sequential_all_archs() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut trainer = PrElmTrainer::new(&default_artifacts_dir(), 2).unwrap();
    trainer.lambda = 1e-4; // matched with the sequential ridge below
    let series = toy_series(900, 3);
    let w = Windowed::from_series(&series, 10).unwrap();
    let (train, test) = w.split(0.8);
    let m = 10usize;

    for arch in ALL_ARCHS {
        let seed = 17;
        // sequential baseline (ridge solve matching the pipeline's λ)
        let mut opts = TrainOptions::new(m, seed);
        opts.ridge = Some(1e-4);
        let seq = SrElmModel::train(arch, &train, &opts).unwrap();
        // parallel pipeline
        let (par, bd) = trainer.train(arch, &train, m, seed).unwrap();

        // β agreement is relative: the Gram system's conditioning
        // amplifies the f32-accumulation noise in the coefficient space,
        // so the functional (RMSE) agreement below is the primary check.
        let norm = |v: &[f64]| v.iter().map(|x| x * x).sum::<f64>().sqrt();
        let dbeta: Vec<f64> = seq.beta.iter().zip(&par.beta).map(|(a, b)| a - b).collect();
        let rel_dbeta = norm(&dbeta) / (1.0 + norm(&seq.beta));
        assert!(rel_dbeta < 0.05, "{}: ‖Δβ‖rel = {rel_dbeta}", arch.name());

        let rmse_seq = seq.rmse(&test);
        let rmse_par = trainer.rmse(&par, &test).unwrap();
        assert!(
            (rmse_seq - rmse_par).abs() < 5e-3 || (rmse_par / rmse_seq) < 1.10,
            "{}: rmse seq {rmse_seq} vs par {rmse_par}",
            arch.name()
        );
        assert!(bd.blocks > 0 && bd.total_s > 0.0);
        println!(
            "{:>7}: Δβ={rel_dbeta:.2e} rmse seq={rmse_seq:.4} par={rmse_par:.4} blocks={}",
            arch.name(),
            bd.blocks
        );
    }
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn parallel_training_is_deterministic_across_worker_counts() {
    if !artifacts_ready() {
        return;
    }
    let series = toy_series(700, 5);
    let w = Windowed::from_series(&series, 10).unwrap();
    let t1 = PrElmTrainer::new(&default_artifacts_dir(), 1).unwrap();
    let t3 = PrElmTrainer::new(&default_artifacts_dir(), 3).unwrap();
    let (m1, _) = t1.train(Arch::Lstm, &w, 10, 99).unwrap();
    let (m3, _) = t3.train(Arch::Lstm, &w, 10, 99).unwrap();
    // in-order fold ⇒ identical accumulation regardless of worker count
    assert_eq!(m1.beta, m3.beta, "determinism across worker counts");
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn padding_does_not_change_solution() {
    if !artifacts_ready() {
        return;
    }
    // n chosen so the tail block is nearly empty (1 valid row)
    let mut trainer = PrElmTrainer::new(&default_artifacts_dir(), 1).unwrap();
    trainer.lambda = 1e-4;
    let series_a = toy_series(256 + 10 + 1, 7); // n = 257 → blocks 256 + 1
    let wa = Windowed::from_series(&series_a, 10).unwrap();
    assert_eq!(wa.n, 257);
    let (model, _) = trainer.train(Arch::Elman, &wa, 10, 5).unwrap();
    // sequential reference on identical data
    let mut opts = TrainOptions::new(10, 5);
    opts.ridge = Some(1e-4);
    let seq = SrElmModel::train(Arch::Elman, &wa, &opts).unwrap();
    let norm = |v: &[f64]| v.iter().map(|x| x * x).sum::<f64>().sqrt();
    let dbeta: Vec<f64> = model.beta.iter().zip(&seq.beta).map(|(a, b)| a - b).collect();
    let rel = norm(&dbeta) / (1.0 + norm(&seq.beta));
    assert!(rel < 0.05, "padded-tail drift {rel}");
    // and the padded-pipeline model must predict as well as the reference
    let r_par = trainer.rmse(&model, &wa).unwrap();
    let r_seq = seq.rmse(&wa);
    assert!((r_par - r_seq).abs() < 5e-3, "rmse drift: par {r_par} seq {r_seq}");
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn breakdown_phases_are_populated() {
    if !artifacts_ready() {
        return;
    }
    let trainer = PrElmTrainer::new(&default_artifacts_dir(), 1).unwrap();
    let series = toy_series(600, 11);
    let w = Windowed::from_series(&series, 10).unwrap();
    let (_m, bd) = trainer.train(Arch::Gru, &w, 10, 1).unwrap();
    assert!(bd.exec_s > 0.0, "exec phase must be measured");
    assert!(bd.h2d_s > 0.0, "h2d phase must be measured");
    assert!(bd.d2h_s >= 0.0);
    assert!(bd.solve_s > 0.0);
    assert!(bd.total_s >= bd.exec_s);
    // Fig 6 claim: init is negligible
    assert!(bd.init_s < bd.total_s * 0.25, "init {} vs total {}", bd.init_s, bd.total_s);
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn narmax_els_improves_or_matches_single_pass() {
    if !artifacts_ready() {
        return;
    }
    let series = toy_series(800, 13);
    let w = Windowed::from_series(&series, 10).unwrap();
    let (train, test) = w.split(0.8);
    let mut trainer = PrElmTrainer::new(&default_artifacts_dir(), 1).unwrap();
    let (els, _) = trainer.train(Arch::Narmax, &train, 10, 21).unwrap();
    let rmse_els = trainer.rmse(&els, &test).unwrap();
    trainer.narmax_els = false;
    let (single, _) = trainer.train(Arch::Narmax, &train, 10, 21).unwrap();
    let rmse_single = trainer.rmse(&single, &test).unwrap();
    assert!(
        rmse_els <= rmse_single * 1.25,
        "ELS {rmse_els} much worse than single-pass {rmse_single}"
    );
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn online_elm_streams_artifact_h_blocks() {
    // OS-ELM extension: stream H blocks straight out of the elm_h
    // artifacts into the recursive least-squares state; the result must
    // match the batch ridge solution over the same rows.
    if !artifacts_ready() {
        return;
    }
    use opt_pr_elm::coordinator::batcher::RowBlockBatcher;
    use opt_pr_elm::elm::{ElmParams, OnlineElm};
    use opt_pr_elm::runtime::{Buf, EnginePool, Manifest};

    let dir = default_artifacts_dir();
    let pool = EnginePool::new(&dir, 1).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let meta = manifest.find("elm_h", "elman", 10, 50).unwrap().clone();

    let series = toy_series(600, 21);
    let w = Windowed::from_series(&series, 10).unwrap();
    let params = ElmParams::init(Arch::Elman, w.s, w.q, meta.m, 4);
    let lambda = 1e-3;
    let mut online = OnlineElm::new(meta.m, lambda);
    let mut all_h: Vec<f32> = Vec::new();
    let mut all_y: Vec<f32> = Vec::new();
    for block in RowBlockBatcher::new(&w, meta.rows) {
        let mut inputs = Vec::new();
        for spec in &meta.inputs {
            let buf = match spec.name.as_str() {
                "x" => Buf::new(spec.shape.clone(), block.x.clone()),
                "yhist" => Buf::new(spec.shape.clone(), block.yhist.clone()),
                "ehist" => Buf::new(spec.shape.clone(), vec![0f32; spec.len()]),
                name => Buf::new(spec.shape.clone(), params.buf(name).to_vec()),
            };
            inputs.push(buf);
        }
        let h = pool.run(&meta.name, inputs).unwrap().remove(0);
        let valid = block.valid;
        online
            .update_block(&h.data[..valid * meta.m], &block.y[..valid], valid)
            .unwrap();
        all_h.extend_from_slice(&h.data[..valid * meta.m]);
        all_y.extend_from_slice(&block.y[..valid]);
    }
    assert_eq!(online.rows_seen(), w.n);

    // batch ridge over the same H
    let hm = opt_pr_elm::linalg::Matrix::from_f32(w.n, meta.m, &all_h);
    let mut g = hm.gram();
    for i in 0..meta.m {
        g[(i, i)] += lambda;
    }
    let yv: Vec<f64> = all_y.iter().map(|&v| v as f64).collect();
    let c = hm.t_matvec(&yv);
    let batch = opt_pr_elm::linalg::cholesky_solve(&g, &c).unwrap();
    for (a, b) in online.beta().iter().zip(&batch) {
        assert!((a - b).abs() < 1e-5, "online {a} vs batch {b}");
    }
}
