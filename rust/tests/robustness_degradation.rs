//! Degradation-ladder conformance sweep (always on — no fault injection).
//!
//! Degenerate-but-finite inputs must never produce a NaN β or a stringly
//! error: every strategy either solves on its primary path or climbs the
//! ridge ladder deterministically, and the [`SolveReport`] says which.
//! The sweep pins, for each architecture × strategy:
//!
//! * degenerate inputs (constant series, all-zero targets) → finite β,
//!   with the *same* ladder rung at every worker count,
//! * rank-deficient systems at the linalg entry points → the same rung
//!   (`Ridge` step 1 at `RIDGE_LADDER[0]`) from QR and TSQR alike,
//! * poisoned rows → quarantined + reported, β bit-equal to training on
//!   the pre-filtered dataset,
//! * fully-poisoned datasets → a typed [`SolveError::AllRowsQuarantined`],
//! * healthy runs → `Primary` rung, zero retries, zero quarantined rows
//!   (the bit-identity contract: the ladder's rung 0 *is* the old solve).

use opt_pr_elm::coordinator::accumulator::SolveStrategy;
use opt_pr_elm::coordinator::pipeline::CpuElmTrainer;
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::elm::{Arch, ALL_ARCHS};
use opt_pr_elm::linalg::{lstsq_qr_report, lstsq_tsqr_report, Matrix, ParallelPolicy};
use opt_pr_elm::robust::{
    as_solve_error, quarantine, DegradationRung, SolveError, RIDGE_LADDER,
};
use opt_pr_elm::util::rng::Rng;

const STRATEGIES: [SolveStrategy; 3] =
    [SolveStrategy::Gram, SolveStrategy::Tsqr, SolveStrategy::DirectQr];
const WORKERS: [usize; 4] = [1, 2, 4, 8];

fn toy_windowed(n: usize, q: usize, seed: u64) -> Windowed {
    let mut rng = Rng::new(seed);
    let mut y = vec![0.3f64, 0.45];
    for t in 2..n + q {
        let v = 0.5 * y[t - 1] + 0.22 * y[t - 2]
            + 0.12 * (t as f64 * 0.17).sin()
            + 0.05 * rng.normal();
        y.push(v);
    }
    let lo = y.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = y.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let z: Vec<f64> = y.iter().map(|v| (v - lo) / (hi - lo)).collect();
    Windowed::from_series(&z, q).unwrap()
}

fn trainer(workers: usize, strategy: SolveStrategy) -> CpuElmTrainer {
    let mut t = CpuElmTrainer::new(workers);
    t.strategy = strategy;
    t.block_rows = 64;
    t
}

#[test]
fn constant_series_degrades_identically_at_every_worker_count() {
    // a constant series makes every H row identical (rank 1 < M): the
    // primary QR/TSQR paths must detect the deficiency and climb the
    // ladder; Gram's ridge handles it on rung 0. Whatever rung fires, it
    // must be the same rung — and the same β bits — at every worker count.
    let w = Windowed::from_series(&vec![0.5f64; 208], 8).unwrap();
    for strategy in STRATEGIES {
        for arch in ALL_ARCHS {
            let mut base: Option<(Vec<f64>, DegradationRung, u32)> = None;
            for workers in WORKERS {
                let (model, bd) = trainer(workers, strategy)
                    .train(arch, &w, 10, 3)
                    .unwrap_or_else(|e| {
                        panic!("{}/{strategy:?} w={workers}: {e}", arch.name())
                    });
                assert!(
                    model.beta.iter().all(|b| b.is_finite()),
                    "{}/{strategy:?} w={workers}: non-finite β",
                    arch.name()
                );
                let r = bd.solve_report;
                assert_ne!(r.rung, DegradationRung::Failed);
                assert_eq!(r.quarantined_rows, 0, "constant rows are finite");
                match &base {
                    None => base = Some((model.beta, r.rung, r.retries)),
                    Some((beta, rung, retries)) => {
                        assert_eq!(
                            beta, &model.beta,
                            "{}/{strategy:?}: β differs at workers={workers}",
                            arch.name()
                        );
                        assert_eq!(
                            (*rung, *retries),
                            (r.rung, r.retries),
                            "{}/{strategy:?}: report differs at workers={workers}",
                            arch.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn duplicated_columns_take_the_same_rung_through_qr_and_tsqr() {
    // a duplicated column is exactly rank-deficient: both direct QR and
    // TSQR must fall back to the same first ladder rung over the normal
    // equations, and say so in the report
    let mut rng = Rng::new(11);
    let (n, m) = (120usize, 6usize);
    let mut a = Matrix::random(n, m, &mut rng);
    for r in 0..n {
        let v = a[(r, 0)];
        a[(r, m - 1)] = v; // duplicate column 0 into the last column
    }
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let policy = ParallelPolicy::with_workers(2);

    let (beta_qr, rep_qr) = lstsq_qr_report(&a, &b, policy).unwrap();
    let (beta_ts, rep_ts) = lstsq_tsqr_report(&a, &b, policy).unwrap();
    for rep in [&rep_qr, &rep_ts] {
        assert_eq!(
            rep.rung,
            DegradationRung::Ridge { step: 1, lambda: RIDGE_LADDER[0] },
            "deficient system must land on ladder rung 1: {}",
            rep.summary()
        );
        assert!(!rep.verdict.is_clean(), "verdict must flag the deficiency");
        assert_eq!(rep.effective_lambda, RIDGE_LADDER[0]);
    }
    assert!(beta_qr.iter().all(|v| v.is_finite()));
    assert!(beta_ts.iter().all(|v| v.is_finite()));
    // both fall back to the identical normal-equations ladder
    for (x, y) in beta_qr.iter().zip(&beta_ts) {
        assert!((x - y).abs() < 1e-8, "qr {x} vs tsqr {y}");
    }
}

#[test]
fn all_zero_targets_stay_on_the_primary_rung() {
    // zero targets are a perfectly conditioned (boring) problem: β ≈ 0 on
    // the primary path, nothing to degrade
    let mut w = toy_windowed(200, 6, 5);
    w.y.iter_mut().for_each(|v| *v = 0.0);
    for strategy in STRATEGIES {
        for arch in [Arch::Elman, Arch::Fc, Arch::Lstm] {
            let (model, bd) = trainer(2, strategy).train(arch, &w, 10, 3).unwrap();
            assert!(model.beta.iter().all(|b| b.is_finite()));
            assert_eq!(
                bd.solve_report.rung,
                DegradationRung::Primary,
                "{}/{strategy:?}: {}",
                arch.name(),
                bd.solve_report.summary()
            );
            assert_eq!(bd.solve_report.retries, 0);
        }
    }
}

#[test]
fn poisoned_rows_are_quarantined_and_reported() {
    let mut w = toy_windowed(300, 6, 7);
    w.x[4 * 6 + 2] = f32::NAN; // row 4's window
    w.y[31] = f32::INFINITY; // row 31's target
    w.yhist[120 * 6] = f32::NAN; // row 120's feedback history

    // the trainer must see exactly what a manual pre-screen would produce
    let screened = quarantine::screen(&w).unwrap();
    let expect_dropped = screened.dropped();
    assert_eq!(expect_dropped, 3);

    for strategy in STRATEGIES {
        for arch in [Arch::Elman, Arch::Jordan, Arch::Narmax] {
            let (model, bd) = trainer(4, strategy).train(arch, &w, 10, 3).unwrap();
            assert!(model.beta.iter().all(|b| b.is_finite()));
            assert_eq!(
                bd.solve_report.quarantined_rows, expect_dropped,
                "{}/{strategy:?}: {}",
                arch.name(),
                bd.solve_report.summary()
            );
            // β must be bit-equal to training on the pre-filtered dataset
            let (clean_model, clean_bd) =
                trainer(4, strategy).train(arch, screened.data(), 10, 3).unwrap();
            assert_eq!(clean_bd.solve_report.quarantined_rows, 0);
            assert_eq!(
                model.beta,
                clean_model.beta,
                "{}/{strategy:?}: quarantined train ≠ pre-filtered train",
                arch.name()
            );
        }
    }
}

#[test]
fn fully_poisoned_dataset_is_a_typed_error_not_a_nan_beta() {
    let mut w = toy_windowed(60, 5, 9);
    w.y.iter_mut().for_each(|v| *v = f32::NAN);
    for strategy in STRATEGIES {
        let err = trainer(2, strategy).train(Arch::Elman, &w, 8, 3).unwrap_err();
        let se = as_solve_error(&err).expect("typed SolveError");
        assert_eq!(*se, SolveError::AllRowsQuarantined { rows: 60 });
    }
}

#[test]
fn healthy_runs_report_primary_with_nothing_to_explain() {
    let w = toy_windowed(400, 6, 13);
    for strategy in STRATEGIES {
        for arch in ALL_ARCHS {
            let (model, bd) = trainer(4, strategy).train(arch, &w, 10, 3).unwrap();
            assert!(model.beta.iter().all(|b| b.is_finite()));
            let r = bd.solve_report;
            assert_eq!(
                r.rung,
                DegradationRung::Primary,
                "{}/{strategy:?}: {}",
                arch.name(),
                r.summary()
            );
            assert_eq!(r.retries, 0, "{}/{strategy:?}", arch.name());
            assert_eq!(r.quarantined_rows, 0, "{}/{strategy:?}", arch.name());
        }
    }
}
