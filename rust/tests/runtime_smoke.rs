//! Runtime ⇄ artifact smoke: the Pallas-lowered H kernels executed through
//! PJRT must match the sequential rust recurrences on identical inputs.
//! This is the cross-layer golden test tying L1/L2 (python, build time) to
//! L3 (rust, run time) without any cross-language RNG coupling: rust
//! generates both the data and the weights.

// Every test below is `#[ignore]`d by default: it needs the real PJRT
// runtime (`pjrt` feature + AOT artifacts from python/compile), which the
// offline build replaces with the erroring xla shim. The in-test
// `artifacts_ready()` guard is kept so `--ignored` runs still self-skip
// gracefully when artifacts are missing. Tracking: ISSUE 2 satellite
// "triage the failing seed tests".
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::elm::{trainer, Arch, ElmParams};
use opt_pr_elm::runtime::{default_artifacts_dir, Buf, EnginePool, Manifest};
use opt_pr_elm::util::rng::Rng;

fn artifacts_ready() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

fn toy_windowed(n_rows: usize, q: usize, seed: u64) -> Windowed {
    let mut rng = Rng::new(seed);
    let mut series = vec![0.5f64];
    for t in 1..(n_rows + q) {
        let prev: f64 = series[t - 1];
        let v: f64 = 0.7 * prev + 0.1 * (t as f64 * 0.3).sin() + 0.05 * rng.normal();
        series.push(v.clamp(-3.0, 3.0));
    }
    Windowed::from_series(&series, q).unwrap()
}

/// Assemble the elm_h ABI input list: x, [yhist, ehist], params...
fn h_inputs(meta: &opt_pr_elm::runtime::ArtifactMeta, w: &Windowed, p: &ElmParams) -> Vec<Buf> {
    let mut inputs = Vec::new();
    for spec in &meta.inputs {
        let buf = match spec.name.as_str() {
            "x" => Buf::new(spec.shape.clone(), w.x.clone()),
            "yhist" => Buf::new(spec.shape.clone(), w.yhist.clone()),
            "ehist" => Buf::new(spec.shape.clone(), vec![0f32; spec.len()]),
            name => Buf::new(spec.shape.clone(), p.buf(name).to_vec()),
        };
        inputs.push(buf);
    }
    inputs
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn elm_h_artifacts_match_sequential_recurrences() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let dir = default_artifacts_dir();
    let pool = EnginePool::new(&dir, 1).unwrap();
    let manifest = Manifest::load(&dir).unwrap();

    for arch_name in ["elman", "jordan", "narmax", "fc", "lstm", "gru"] {
        let meta = manifest.find("elm_h", arch_name, 10, 50).unwrap().clone();
        let arch = Arch::parse(arch_name).unwrap();
        let w = toy_windowed(meta.rows, meta.q, 42);
        assert_eq!(w.n, meta.rows);
        let params = ElmParams::init(arch, meta.s, meta.q, meta.m, 7);

        let out = pool.run(&meta.name, h_inputs(&meta, &w, &params)).unwrap();
        assert_eq!(out.len(), 1, "{arch_name}");
        let h_pjrt = &out[0];
        assert_eq!(h_pjrt.dims, vec![meta.rows, meta.m]);

        let h_seq = trainer::hidden_matrix(&params, &w, None);
        let mut max_err = 0f64;
        for i in 0..meta.rows {
            for j in 0..meta.m {
                let a = h_pjrt.data[i * meta.m + j] as f64;
                let b = h_seq[(i, j)];
                max_err = max_err.max((a - b).abs());
            }
        }
        assert!(max_err < 2e-4, "{arch_name}: max |pjrt - seq| = {max_err}");
        println!("{arch_name}: max_err = {max_err:.2e} OK");
    }
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn gram_artifact_matches_h_products() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = default_artifacts_dir();
    let pool = EnginePool::new(&dir, 1).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let meta = manifest.find("elm_gram", "elman", 10, 50).unwrap().clone();
    let arch = Arch::parse("elman").unwrap();
    let w = toy_windowed(meta.rows, meta.q, 9);
    let params = ElmParams::init(arch, meta.s, meta.q, meta.m, 3);

    let mut inputs = Vec::new();
    for spec in &meta.inputs {
        let buf = match spec.name.as_str() {
            "x" => Buf::new(spec.shape.clone(), w.x.clone()),
            "y" => Buf::new(spec.shape.clone(), w.y.clone()),
            "mask" => Buf::new(spec.shape.clone(), vec![1f32; meta.rows]),
            name => Buf::new(spec.shape.clone(), params.buf(name).to_vec()),
        };
        inputs.push(buf);
    }
    let out = pool.run(&meta.name, inputs).unwrap();
    assert_eq!(out.len(), 2);
    let (hth, hty) = (&out[0], &out[1]);
    assert_eq!(hth.dims, vec![meta.m, meta.m]);
    assert_eq!(hty.dims, vec![meta.m]);

    // compare against sequential H products (f32 gram accumulates error:
    // tolerance scaled for n = 256 terms)
    let h = trainer::hidden_matrix(&params, &w, None);
    let g = h.gram();
    let y: Vec<f64> = w.y.iter().map(|&v| v as f64).collect();
    let c = h.t_matvec(&y);
    let mut max_g = 0f64;
    for a in 0..meta.m {
        for b in 0..meta.m {
            max_g = max_g.max((hth.data[a * meta.m + b] as f64 - g[(a, b)]).abs());
        }
    }
    let max_c = (0..meta.m)
        .map(|j| (hty.data[j] as f64 - c[j]).abs())
        .fold(0f64, f64::max);
    assert!(max_g < 1e-2, "HtH err {max_g}");
    assert!(max_c < 1e-2, "HtY err {max_c}");
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn engine_rejects_bad_inputs() {
    if !artifacts_ready() {
        return;
    }
    let dir = default_artifacts_dir();
    let pool = EnginePool::new(&dir, 1).unwrap();
    let err = pool.run("elm_h_elman_r256_s1_q10_m50", vec![]).unwrap_err();
    assert!(format!("{err:#}").contains("inputs"), "{err:#}");
    let err2 = pool.run("no_such_artifact", vec![]).unwrap_err();
    assert!(format!("{err2:#}").contains("manifest"), "{err2:#}");
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn pool_round_robin_with_two_workers() {
    if !artifacts_ready() {
        return;
    }
    let dir = default_artifacts_dir();
    let pool = EnginePool::new(&dir, 2).unwrap();
    assert_eq!(pool.n_workers(), 2);
    let manifest = Manifest::load(&dir).unwrap();
    let meta = manifest.find("elm_h", "elman", 10, 50).unwrap().clone();
    let w = toy_windowed(meta.rows, meta.q, 1);
    let p = ElmParams::init(Arch::Elman, meta.s, meta.q, meta.m, 1);
    let inputs = h_inputs(&meta, &w, &p);
    let a = pool.run(&meta.name, inputs.clone()).unwrap();
    let b = pool.run(&meta.name, inputs).unwrap();
    assert_eq!(a[0].data, b[0].data, "workers must agree bit-for-bit");
    let stats = pool.stats();
    assert_eq!(stats.executions, 2);
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn corrupt_hlo_file_yields_error_not_crash() {
    if !artifacts_ready() {
        return;
    }
    // stage a corrupt artifact in a temp dir with a valid manifest entry
    let tmp = std::env::temp_dir().join(format!("optprelm_corrupt_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let manifest_json = r#"{
      "artifacts": [
        {"name": "bad", "file": "bad.hlo.txt", "kind": "elm_h", "arch": "elman",
         "variant": "opt", "rows": 4, "block_rows": 2, "s": 1, "q": 2, "m": 2,
         "inputs": [{"name": "x", "shape": [4, 1, 2], "dtype": "f32"}],
         "outputs": ["h"]}
      ]
    }"#;
    std::fs::write(tmp.join("manifest.json"), manifest_json).unwrap();
    std::fs::write(tmp.join("bad.hlo.txt"), "HloModule utterly { broken").unwrap();
    let pool = EnginePool::new(&tmp, 1).unwrap();
    let err = pool
        .run("bad", vec![Buf::new(vec![4, 1, 2], vec![0.0; 8])])
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("bad") || msg.contains("pars"), "{msg}");
    // the engine thread must survive the failure
    let err2 = pool.run("bad", vec![]).unwrap_err();
    assert!(!format!("{err2:#}").is_empty());
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn missing_artifact_file_is_reported() {
    if !artifacts_ready() {
        return;
    }
    let tmp = std::env::temp_dir().join(format!("optprelm_missing_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let manifest_json = r#"{
      "artifacts": [
        {"name": "ghost", "file": "ghost.hlo.txt", "kind": "elm_h", "arch": "elman",
         "variant": "opt", "rows": 4, "block_rows": 2, "s": 1, "q": 2, "m": 2,
         "inputs": [{"name": "x", "shape": [4, 1, 2], "dtype": "f32"}],
         "outputs": ["h"]}
      ]
    }"#;
    std::fs::write(tmp.join("manifest.json"), manifest_json).unwrap();
    let pool = EnginePool::new(&tmp, 1).unwrap();
    let err = pool
        .run("ghost", vec![Buf::new(vec![4, 1, 2], vec![0.0; 8])])
        .unwrap_err();
    assert!(format!("{err:#}").contains("ghost"), "{err:#}");
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
#[ignore = "needs PJRT artifacts (python/compile/aot.py + the `pjrt` feature); the default build links the offline xla shim — run with `cargo test -- --ignored` on a deployment box"]
fn pool_survives_many_concurrent_callers() {
    if !artifacts_ready() {
        return;
    }
    let dir = default_artifacts_dir();
    let pool = std::sync::Arc::new(EnginePool::new(&dir, 3).unwrap());
    let manifest = Manifest::load(&dir).unwrap();
    let meta = manifest.find("elm_h", "gru", 10, 50).unwrap().clone();
    let w = toy_windowed(meta.rows, meta.q, 2);
    let p = ElmParams::init(Arch::Gru, meta.s, meta.q, meta.m, 2);
    let inputs = h_inputs(&meta, &w, &p);
    let mut handles = Vec::new();
    for _ in 0..12 {
        let pool = pool.clone();
        let name = meta.name.clone();
        let inputs = inputs.clone();
        handles.push(std::thread::spawn(move || {
            pool.run(&name, inputs).unwrap()[0].data.clone()
        }));
    }
    let first = handles.remove(0).join().unwrap();
    for h in handles {
        assert_eq!(h.join().unwrap(), first, "all callers see identical results");
    }
}
