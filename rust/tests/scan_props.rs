//! Sequence-parallel recurrence conformance: the chunked executors vs the
//! sequential oracle kernels (`RecurrenceMode::Sequential`).
//!
//! Three tiers of guarantee, each pinned here:
//!
//! 1. **FC (and the recurrence-free Jordan/NARMAX): bit-identity.** The FC
//!    chunked executor precomputes cross-chunk coupling GEMMs in parallel
//!    but folds every term in the oracle's order, so its output is the
//!    oracle's exact bits at any chunk size and worker count, on both
//!    `Precision` wires. Scan-of-one-chunk (`chunk >= q`, horizon 0/1) is
//!    the sequential walk by construction.
//! 2. **Elman/LSTM/GRU: warm-up envelope.** The chunked mode evaluates the
//!    tail chunk plus a `warmup`-step prefix from a zero state. When the
//!    warm-up reaches `t = 0` the run is bitwise the sequential kernel
//!    (same loop, same range). Otherwise the truncated history drifts the
//!    output within the documented per-arch envelope: the lag-1 leaky
//!    cells (LSTM/GRU) contract the initial-state discrepancy
//!    geometrically over the warm-up (≤ 0.5 per element at the suite's
//!    warm-up), while Elman's full-lag feedback only has the trivial
//!    activation bound (≤ 2.0 — its exactness needs the warm-up to span
//!    the horizon).
//! 3. **The generic affine scan** (`linalg::scan::scan_affine`): single
//!    chunk ≡ the stepping reference bitwise, and worker-count
//!    bit-invariance at every chunk size.

use opt_pr_elm::elm::arch::{self, HBlock, SampleBlock};
use opt_pr_elm::elm::trainer::hidden_matrix_policy;
use opt_pr_elm::elm::{Arch, ElmParams};
use opt_pr_elm::linalg::scan::{scan_affine, scan_affine_reference, Affine};
use opt_pr_elm::linalg::{Matrix, ParallelPolicy, Precision, RecurrenceMode};
use opt_pr_elm::util::rng::Rng;

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Owned random sample-block buffers (x, yhist, ehist).
fn block_bufs(rows: usize, s: usize, q: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let x = rng.normals_f32(rows * s * q);
    let yh: Vec<f32> = rng.normals_f32(rows * q).iter().map(|v| v * 0.1).collect();
    let eh: Vec<f32> = rng.normals_f32(rows * q).iter().map(|v| v * 0.1).collect();
    (x, yh, eh)
}

fn assert_hblock_bits_eq(a: &HBlock, b: &HBlock, ctx: &str) {
    match (a, b) {
        (HBlock::F64(a), HBlock::F64(b)) => assert_eq!(a, b, "{ctx}"),
        (HBlock::F32(a), HBlock::F32(b)) => assert_eq!(a, b, "{ctx}"),
        _ => panic!("{ctx}: precision wires differ"),
    }
}

fn chunked(chunk: usize, warmup: usize) -> RecurrenceMode {
    RecurrenceMode::Chunked { chunk, warmup }
}

/// FC blocked scan: bit-identical to the sequential kernel at 1/2/4/8
/// workers × chunk sizes {1, 7, 64, horizon}, ragged tails (q = 13 vs
/// chunk 7), both precision wires.
#[test]
fn fc_chunked_is_bit_identical_any_workers_chunks_wires() {
    let (s, q, m, rows) = (2, 13, 6, 10);
    let p = ElmParams::init(Arch::Fc, s, q, m, 41);
    let (x, yh, eh) = block_bufs(rows, s, q, 8);
    let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };
    for precision in [Precision::F64, Precision::MixedF32] {
        let oracle = arch::h_block_policy(
            &p,
            &blk,
            ParallelPolicy::sequential().with_precision(precision),
        );
        for chunk in [1usize, 7, 64, q] {
            for workers in WORKER_COUNTS {
                let got = arch::h_block_policy(
                    &p,
                    &blk,
                    ParallelPolicy::with_workers(workers)
                        .with_precision(precision)
                        .with_recurrence(chunked(chunk, 0)),
                );
                assert_hblock_bits_eq(
                    &oracle,
                    &got,
                    &format!("{precision:?} chunk={chunk} workers={workers}"),
                );
            }
        }
    }
}

/// Degenerate horizons: q = 0 and q = 1 have a schedule of at most one
/// chunk, which must be the sequential walk itself — bit for bit.
#[test]
fn fc_chunked_degenerate_horizons_are_sequential() {
    for q in [0usize, 1] {
        let (s, m, rows) = (2, 4, 5);
        let p = ElmParams::init(Arch::Fc, s, q, m, 42);
        let (x, yh, eh) = block_bufs(rows, s, q, 9);
        let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };
        for precision in [Precision::F64, Precision::MixedF32] {
            let oracle = arch::h_block_policy(
                &p,
                &blk,
                ParallelPolicy::sequential().with_precision(precision),
            );
            for chunk in [1usize, 4] {
                let got = arch::h_block_policy(
                    &p,
                    &blk,
                    ParallelPolicy::with_workers(4)
                        .with_precision(precision)
                        .with_recurrence(chunked(chunk, 2)),
                );
                assert_hblock_bits_eq(&oracle, &got, &format!("q={q} chunk={chunk}"));
            }
        }
    }
}

/// The recurrence-free architectures have nothing to chunk: chunked mode
/// routes to the very same kernel and must be bit-identical at any
/// chunk/warmup/worker combination.
#[test]
fn jordan_narmax_chunked_is_identically_sequential() {
    let (s, q, m, rows) = (2, 12, 5, 9);
    for arch in [Arch::Jordan, Arch::Narmax] {
        let p = ElmParams::init(arch, s, q, m, 43);
        let (x, yh, eh) = block_bufs(rows, s, q, 10);
        let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };
        for precision in [Precision::F64, Precision::MixedF32] {
            let oracle = arch::h_block_policy(
                &p,
                &blk,
                ParallelPolicy::sequential().with_precision(precision),
            );
            for (chunk, warmup) in [(1usize, 0usize), (5, 3), (64, 0)] {
                let got = arch::h_block_policy(
                    &p,
                    &blk,
                    ParallelPolicy::with_workers(4)
                        .with_precision(precision)
                        .with_recurrence(chunked(chunk, warmup)),
                );
                assert_hblock_bits_eq(
                    &oracle,
                    &got,
                    &format!("{arch:?} {precision:?} chunk={chunk}"),
                );
            }
        }
    }
}

/// Max |chunked − sequential| per element over the block.
fn envelope(p: &ElmParams, blk: &SampleBlock, mode: RecurrenceMode) -> f64 {
    let seq = arch::h_block_policy(p, blk, ParallelPolicy::sequential()).into_f64();
    let got = arch::h_block_policy(
        p,
        blk,
        ParallelPolicy::with_workers(4).with_recurrence(mode),
    )
    .into_f64();
    let mut worst = 0f64;
    for (a, b) in got.data().iter().zip(seq.data()) {
        assert!(a.is_finite(), "chunked output must stay finite");
        worst = worst.max((a - b).abs());
    }
    worst
}

/// The stateful nonlinear architectures under chunked warm-up: exact when
/// the warm-up reaches t = 0, inside the documented per-arch envelope
/// otherwise (LSTM/GRU contract the truncated state geometrically; Elman
/// only has the trivial activation bound).
#[test]
fn stateful_archs_obey_the_documented_warmup_envelope() {
    let (s, q, m, rows) = (2, 96, 8, 10);
    let chunk = 32; // last chunk starts at t = 64
    for arch_kind in [Arch::Elman, Arch::Lstm, Arch::Gru] {
        let p = ElmParams::init(arch_kind, s, q, m, 44);
        let (x, yh, eh) = block_bufs(rows, s, q, 11);
        let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };

        // warm-up spanning the horizon (ws = 0): bitwise the oracle
        let seq = arch::h_block_policy(&p, &blk, ParallelPolicy::sequential());
        let exact = arch::h_block_policy(
            &p,
            &blk,
            ParallelPolicy::with_workers(4).with_recurrence(chunked(chunk, q)),
        );
        assert_hblock_bits_eq(&seq, &exact, &format!("{arch_kind:?} full warm-up"));

        // truncated warm-ups: the envelope is the documented per-arch
        // bound — and always the trivial activation-range cap
        let cap = match arch_kind {
            // lag-1 leaky cells contract the zero-state discrepancy
            // geometrically over the 48-step warm-up
            Arch::Lstm | Arch::Gru => 0.5,
            // full-lag feedback: only the activation range bounds it
            _ => 2.0,
        };
        for warmup in [0usize, 48] {
            let e = envelope(&p, &blk, chunked(chunk, warmup));
            assert!(
                e <= 2.0,
                "{arch_kind:?} warmup={warmup}: {e} breaks the activation cap"
            );
            if warmup == 48 {
                assert!(
                    e <= cap,
                    "{arch_kind:?} warmup={warmup}: envelope {e} > documented {cap}"
                );
            }
        }
    }
}

/// The trainer-level block stitch (`hidden_matrix_policy`) carries the
/// recurrence mode through to every row block: FC stays bit-identical to
/// the sequential stitch on both wires.
#[test]
fn hidden_matrix_policy_carries_chunked_mode_bit_identically_for_fc() {
    use opt_pr_elm::data::window::Windowed;
    let mut rng = Rng::new(12);
    let q = 10;
    let mut y = vec![0.3f64, 0.45];
    for t in 2..300 + q {
        let v = 0.55 * y[t - 1] + 0.2 * y[t - 2]
            + 0.1 * (t as f64 * 0.23).sin()
            + 0.04 * rng.normal();
        y.push(v);
    }
    let w = Windowed::from_series(&y, q).unwrap();
    let p = ElmParams::init(Arch::Fc, w.s, w.q, 7, 45);
    for precision in [Precision::F64, Precision::MixedF32] {
        let seq = hidden_matrix_policy(
            &p,
            &w,
            None,
            ParallelPolicy::sequential().with_precision(precision),
        );
        for workers in [1usize, 4] {
            let got = hidden_matrix_policy(
                &p,
                &w,
                None,
                ParallelPolicy::with_workers(workers)
                    .with_precision(precision)
                    .with_recurrence(chunked(4, 0)),
            );
            assert_hblock_bits_eq(
                &seq,
                &got,
                &format!("{precision:?} workers={workers}"),
            );
        }
    }
}

/// The generic affine scan from the public surface: one chunk is the
/// stepping reference bitwise; the worker count never changes bits at any
/// chunk size.
#[test]
fn affine_scan_public_surface_contract() {
    let n = 4;
    let mut rng = Rng::new(13);
    let steps: Vec<Affine> = (0..23)
        .map(|_| {
            let mut a = Matrix::random(n, n, &mut rng);
            for v in a.data_mut() {
                *v *= 0.3;
            }
            let b = (0..n).map(|_| rng.normal()).collect();
            Affine { a, b }
        })
        .collect();
    let h0 = vec![0.25; n];
    let reference = scan_affine_reference(&steps, &h0);
    let one_chunk =
        scan_affine(&steps, &h0, steps.len(), ParallelPolicy::with_workers(4)).unwrap();
    assert_eq!(one_chunk, reference, "single chunk must be the oracle bits");
    for chunk in [1usize, 5, 23] {
        let base = scan_affine(&steps, &h0, chunk, ParallelPolicy::sequential()).unwrap();
        for workers in WORKER_COUNTS {
            let got =
                scan_affine(&steps, &h0, chunk, ParallelPolicy::with_workers(workers))
                    .unwrap();
            assert_eq!(got, base, "chunk={chunk} workers={workers}");
        }
    }
}
