//! Service conformance suite: the deadline-aware async `FleetService`
//! must add **zero numeric drift** on top of the fleet contract, and its
//! crash-safe journal must restore tenants **bit-identically**.
//!
//! * With no capacity bound, no deadlines, and no faults, the async
//!   submit/run_to_idle path produces per-tenant β bit-identical to one
//!   synchronous `FleetTrainer::drain` of the same submissions — at
//!   1/2/4/8 workers.
//! * Truncating the journal at **every** record boundary (a clean crash
//!   between appends) recovers exactly the prefix's tenants, bit-identical
//!   to the live cache at that point — again worker-count invariant.
//! * Truncating *inside* the final record (a torn append) or flipping a
//!   byte fails the checksum and comes back as a typed
//!   `ServiceError::JournalTorn` — never a panic — with the intact prefix
//!   still restored.
//! * A journal written after `elm::online` RLS warm updates replays into
//!   a cold service whose cache matches the live one bit-for-bit, and one
//!   further identical update lands bit-identically on both.

use opt_pr_elm::coordinator::fleet::{FleetOutcome, FleetRequest, FleetTrainer};
use opt_pr_elm::coordinator::{FleetService, ServiceConfig};
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::elm::Arch;
use opt_pr_elm::linalg::ParallelPolicy;
use opt_pr_elm::robust::TenantJournal;

fn series(n: usize, seed: u64) -> Vec<f64> {
    let mut v = Vec::with_capacity(n);
    let mut x = 0.37 + (seed % 97) as f64 * 1e-3;
    for _ in 0..n {
        x = 3.7 * x * (1.0 - x);
        v.push(x - 0.5);
    }
    v
}

fn windows(n: usize, q: usize, seed: u64) -> Windowed {
    Windowed::from_series(&series(n + q, seed), q).expect("windowed")
}

fn train_req(tenant: &str, m: usize, seed: u64) -> FleetRequest {
    FleetRequest::Train {
        tenant: tenant.to_string(),
        arch: Arch::Elman,
        m,
        seed,
        data: windows(120 + 7 * (seed as usize % 5), 3, seed),
    }
}

fn update_req(tenant: &str, seed: u64) -> FleetRequest {
    FleetRequest::Update { tenant: tenant.to_string(), data: windows(40, 3, seed) }
}

fn beta_bits(trainer: &FleetTrainer, tenant: &str) -> Vec<u64> {
    trainer
        .model(tenant)
        .unwrap_or_else(|| panic!("tenant {tenant} not cached"))
        .beta
        .iter()
        .map(|v| v.to_bits())
        .collect()
}

fn service(workers: usize) -> FleetService {
    let pol = ParallelPolicy::with_workers(workers);
    FleetService::with_config(FleetTrainer::with_policy(pol), ServiceConfig::default())
}

/// The submission sequence every test uses: three trains, then warm
/// updates on two of the tenants.
fn submit_all(svc: &mut FleetService) {
    for (i, t) in ["a", "b", "c"].iter().enumerate() {
        svc.submit(train_req(t, 8, 11 + i as u64), None, 0).unwrap();
    }
    svc.run_to_idle().iter().for_each(|c| assert!(c.outcome.is_ok(), "{c:?}"));
    svc.submit(update_req("a", 31), None, 0).unwrap();
    svc.submit(update_req("b", 32), None, 0).unwrap();
    svc.run_to_idle().iter().for_each(|c| assert!(c.outcome.is_ok(), "{c:?}"));
}

/// Tentpole conformance: unbounded/no-deadline/no-fault async service ≡
/// synchronous drain, bit-for-bit, at every worker count.
#[test]
fn async_beta_is_bitwise_sync_at_every_worker_count() {
    for workers in [1usize, 2, 4, 8] {
        let pol = ParallelPolicy::with_workers(workers);

        let mut sync = FleetTrainer::with_policy(pol);
        for (i, t) in ["a", "b", "c"].iter().enumerate() {
            sync.submit(train_req(t, 8, 11 + i as u64)).unwrap();
        }
        let out = sync.drain();
        assert!(out.iter().all(|(_, o)| matches!(o, FleetOutcome::Trained { .. })));
        sync.submit(update_req("a", 31)).unwrap();
        sync.submit(update_req("b", 32)).unwrap();
        let out = sync.drain();
        assert!(out.iter().all(|(_, o)| matches!(o, FleetOutcome::Updated { .. })));

        let mut svc = service(workers);
        submit_all(&mut svc);

        for t in ["a", "b", "c"] {
            assert_eq!(
                beta_bits(&sync, t),
                beta_bits(svc.trainer(), t),
                "workers={workers} tenant={t}: async β drifted from sync drain"
            );
        }
        let stats = svc.stats();
        assert_eq!(
            (stats.shed, stats.retries, stats.deadline_miss),
            (0, 0, 0),
            "healthy run must not shed, retry, or miss deadlines"
        );
    }
}

/// Crash-at-every-boundary: truncating the journal at each record
/// boundary recovers exactly the tenants appended so far, bit-identical
/// to the live models — at every worker count.
#[test]
fn recovery_at_every_record_boundary_is_bit_identical() {
    for workers in [1usize, 2, 4, 8] {
        let mut svc = service(workers);
        submit_all(&mut svc);
        let journal = svc.journal().clone();
        let bounds = journal.record_boundaries();
        // header + 3 trains + 2 updates
        assert_eq!(bounds.len(), 6, "workers={workers}: unexpected journal layout");

        for (k, &cut) in bounds.iter().enumerate() {
            let crashed =
                TenantJournal::from_bytes(journal.as_bytes()[..cut].to_vec());
            let mut cold = service(workers);
            let (applied, torn) = cold.warm_from(&crashed);
            assert!(
                torn.is_none(),
                "workers={workers} boundary {k}: clean crash must not read torn"
            );
            // records land in append order a, b, c, a-upd, b-upd: the
            // tenant set after k records is a prefix, with updates
            // superseding in place
            let expect: &[&str] = match k {
                0 => &[],
                1 => &["a"],
                2 => &["a", "b"],
                _ => &["a", "b", "c"],
            };
            assert_eq!(
                applied,
                expect.len(),
                "workers={workers} boundary {k}: wrong tenant count restored"
            );
            for t in expect {
                assert!(cold.trainer().has_model(t));
            }
            // at the final boundary the recovered cache must equal the
            // live one bit-for-bit (updates included)
            if k == bounds.len() - 1 {
                for t in ["a", "b", "c"] {
                    assert_eq!(
                        beta_bits(svc.trainer(), t),
                        beta_bits(cold.trainer(), t),
                        "workers={workers} tenant={t}: recovery drifted"
                    );
                }
            }
        }
    }
}

/// Torn final record: a truncation inside the last frame (and separately
/// a flipped payload byte) is detected by the checksum and reported as a
/// typed `JournalTorn` — the intact prefix still restores, nothing
/// panics.
#[test]
fn torn_final_record_is_typed_not_a_panic() {
    let mut svc = service(2);
    submit_all(&mut svc);
    let journal = svc.journal().clone();
    let bounds = journal.record_boundaries();
    let last_start = bounds[bounds.len() - 2];
    let last_end = bounds[bounds.len() - 1];

    // every torn length inside the final frame: typed, prefix intact
    for cut in [last_start + 1, last_start + 5, last_end - 1] {
        let torn_j = TenantJournal::from_bytes(journal.as_bytes()[..cut].to_vec());
        let mut cold = service(2);
        let (applied, torn) = cold.warm_from(&torn_j);
        assert_eq!(applied, 3, "prefix tenants must survive a torn tail (cut {cut})");
        let err = torn.expect("a mid-frame truncation must be reported");
        assert_eq!(err.class(), "journal-torn", "{err}");
    }

    // bit flip inside the final frame's payload: checksum catches it
    let mut bytes = journal.as_bytes().to_vec();
    bytes[last_start + 6] ^= 0x40;
    let mut cold = service(2);
    let (applied, torn) = cold.warm_from(&TenantJournal::from_bytes(bytes));
    assert_eq!(applied, 3);
    assert_eq!(torn.map(|e| e.class()), Some("journal-torn"));

    // pure garbage never panics either
    let mut cold = service(2);
    let (applied, torn) =
        cold.warm_from(&TenantJournal::from_bytes(vec![0xAB; 57]));
    assert_eq!(applied, 0);
    assert!(torn.is_some());
}

/// RLS continuity: a journal written after warm updates replays into a
/// cold service bit-identical to the live cache, and one further
/// identical update lands bit-identically on both — the recovered RLS
/// state (P, λ, rows seen) is the live state, not an approximation.
#[test]
fn replay_after_rls_updates_matches_live_cache() {
    let mut live = service(2);
    submit_all(&mut live);

    let mut cold = service(2);
    let (applied, torn) = cold.warm_from(&live.journal().clone());
    assert_eq!((applied, torn), (3, None));
    for t in ["a", "b", "c"] {
        assert_eq!(
            beta_bits(live.trainer(), t),
            beta_bits(cold.trainer(), t),
            "tenant {t}: replayed cache drifted from live"
        );
    }

    // one more identical update on both sides: the warm path must
    // continue bit-identically from the recovered state
    for svc in [&mut live, &mut cold] {
        svc.submit(update_req("a", 77), None, 0).unwrap();
        let done = svc.run_to_idle();
        assert!(done.iter().all(|c| matches!(
            c.outcome,
            Ok(FleetOutcome::Updated { .. })
        )));
    }
    assert_eq!(
        beta_bits(live.trainer(), "a"),
        beta_bits(cold.trainer(), "a"),
        "post-recovery update diverged: RLS state was not restored exactly"
    );
}
