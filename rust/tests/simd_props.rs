//! SIMD-vs-scalar bit-identity property suite (ISSUE 5).
//!
//! The `linalg::simd` microkernels dispatch to AVX2 at runtime; this
//! suite pins the contract that dispatch **never moves a bit**: every
//! dispatched kernel is compared against its public `*_scalar` twin (the
//! exact code the fallback path runs) across remainder-lane sweeps
//! (`n % 8 ∈ 0..8`), degenerate shapes (0×n, 1×1, tall-skinny), NaN/inf
//! propagation, both precisions, and 1/2/4/8 workers — plus the
//! `FmaMode::Relaxed` envelope. On a non-AVX2 host the comparisons are
//! trivially equal (dispatch == scalar), so the suite is green on every
//! ISA; on an AVX2 host it is the cross-ISA reproducibility proof.

use opt_pr_elm::linalg::{simd, FmaMode, Matrix, MatrixF32, ParallelPolicy};
use opt_pr_elm::util::rng::Rng;

fn randv(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

fn randv32(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::random(rows, cols, &mut rng)
}

fn random_f32(rows: usize, cols: usize, seed: u64) -> MatrixF32 {
    MatrixF32::from_vec(rows, cols, randv32(rows * cols, seed))
}

/// Bit-level slice equality — NaN-safe (comparing payload bits, which
/// `==` on floats is not).
fn assert_bits_eq(a: &[f64], b: &[f64], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: bit mismatch at {i}: {x:e} vs {y:e}"
        );
    }
}

/// Unblocked ijk reference (scalar by construction) — the oracle the
/// blocked + SIMD GEMM must reproduce bit for bit.
fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows);
    let mut out = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for k in 0..a.cols {
            let v = a[(i, k)];
            for j in 0..b.cols {
                out[(i, j)] += v * b[(k, j)];
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// kernel-level pairs: dispatched vs scalar twin, every remainder-lane count
// ---------------------------------------------------------------------------

#[test]
fn gemm_tile_f64_bits_match_scalar_across_tails() {
    // jb 1..=17 covers jb % 8 ∈ 0..8 twice (8-lane, 4-lane, and scalar
    // remainder columns); kb covers the 1, partial, and full panel depths
    for jb in 1..=17usize {
        for &kb in &[1usize, 5, 64] {
            let ldo = jb + 3; // strided output slab, like a real C row
            let a: Vec<Vec<f64>> =
                (0..4).map(|r| randv(kb, (jb * 100 + kb * 10 + r) as u64)).collect();
            let panel = randv(kb * jb, (jb * 7 + kb) as u64);
            let base = randv(3 * ldo + jb, (jb * 13 + kb) as u64);
            let (mut d, mut s) = (base.clone(), base);
            simd::gemm_tile_f64(
                [&a[0], &a[1], &a[2], &a[3]],
                &panel,
                jb,
                &mut d,
                ldo,
                FmaMode::Exact,
            );
            simd::gemm_tile_f64_scalar([&a[0], &a[1], &a[2], &a[3]], &panel, jb, &mut s, ldo);
            assert_bits_eq(&d, &s, &format!("gemm_tile_f64 jb={jb} kb={kb}"));
        }
    }
}

#[test]
fn gemm_row_f64_bits_match_scalar_across_tails() {
    for jb in 1..=17usize {
        for &kb in &[1usize, 5, 64] {
            let a = randv(kb, (jb + kb) as u64);
            let panel = randv(kb * jb, (jb * 3 + kb) as u64);
            let base = randv(jb, (jb * 5 + kb) as u64);
            let (mut d, mut s) = (base.clone(), base);
            simd::gemm_row_f64(&a, &panel, jb, &mut d, FmaMode::Exact);
            simd::gemm_row_f64_scalar(&a, &panel, jb, &mut s);
            assert_bits_eq(&d, &s, &format!("gemm_row_f64 jb={jb} kb={kb}"));
        }
    }
}

#[test]
fn gemm_widen_kernels_bits_match_scalar_across_tails() {
    for jb in 1..=17usize {
        for &kb in &[1usize, 5, 64] {
            let ldo = jb + 2;
            let a: Vec<Vec<f32>> =
                (0..4).map(|r| randv32(kb, (jb * 90 + kb * 9 + r) as u64)).collect();
            let panel = randv32(kb * jb, (jb * 11 + kb) as u64);
            let base = randv(3 * ldo + jb, (jb * 17 + kb) as u64);

            let (mut d, mut s) = (base.clone(), base.clone());
            simd::gemm_tile_widen(
                [&a[0], &a[1], &a[2], &a[3]],
                &panel,
                jb,
                &mut d,
                ldo,
                FmaMode::Exact,
            );
            simd::gemm_tile_widen_scalar([&a[0], &a[1], &a[2], &a[3]], &panel, jb, &mut s, ldo);
            assert_bits_eq(&d, &s, &format!("gemm_tile_widen jb={jb} kb={kb}"));

            let (mut d, mut s) = (base[..jb].to_vec(), base[..jb].to_vec());
            simd::gemm_row_widen(&a[0], &panel, jb, &mut d, FmaMode::Exact);
            simd::gemm_row_widen_scalar(&a[0], &panel, jb, &mut s);
            assert_bits_eq(&d, &s, &format!("gemm_row_widen jb={jb} kb={kb}"));
        }
    }
}

#[test]
fn gram_kernels_bits_match_scalar_across_tails() {
    for n in 1..=17usize {
        let rows: Vec<Vec<f64>> = (0..4).map(|r| randv(n, (n * 10 + r) as u64)).collect();
        let rows32: Vec<Vec<f32>> = (0..4).map(|r| randv32(n, (n * 20 + r) as u64)).collect();
        let x = [1.5, -0.25, 0.125, 3.0];
        let x32 = [1.5f32, -0.25, 0.125, 3.0];
        let base = randv(n, 400 + n as u64);

        let (mut d, mut s) = (base.clone(), base.clone());
        simd::gram4_f64(x, [&rows[0], &rows[1], &rows[2], &rows[3]], &mut d, FmaMode::Exact);
        simd::gram4_f64_scalar(x, [&rows[0], &rows[1], &rows[2], &rows[3]], &mut s);
        assert_bits_eq(&d, &s, &format!("gram4_f64 n={n}"));

        let (mut d, mut s) = (base.clone(), base);
        simd::gram4_widen(
            x32,
            [&rows32[0], &rows32[1], &rows32[2], &rows32[3]],
            &mut d,
            FmaMode::Exact,
        );
        simd::gram4_widen_scalar(x32, [&rows32[0], &rows32[1], &rows32[2], &rows32[3]], &mut s);
        assert_bits_eq(&d, &s, &format!("gram4_widen n={n}"));
    }
}

#[test]
fn axpy_family_bits_match_scalar_including_empty() {
    for n in 0..=17usize {
        let x = randv(n, 600 + n as u64);
        let x32 = randv32(n, 700 + n as u64);
        let base = randv(n, 800 + n as u64);

        let (mut d, mut s) = (base.clone(), base.clone());
        simd::axpy_f64(-0.7, &x, &mut d);
        simd::axpy_f64_scalar(-0.7, &x, &mut s);
        assert_bits_eq(&d, &s, &format!("axpy_f64 n={n}"));

        let (mut d, mut s) = (base.clone(), base.clone());
        simd::axpy_sub_f64(-0.7, &x, &mut d);
        simd::axpy_sub_f64_scalar(-0.7, &x, &mut s);
        assert_bits_eq(&d, &s, &format!("axpy_sub_f64 n={n}"));

        let (mut d, mut s) = (base.clone(), base.clone());
        simd::axpy_widen(-0.7, &x32, &mut d);
        simd::axpy_widen_scalar(-0.7, &x32, &mut s);
        assert_bits_eq(&d, &s, &format!("axpy_widen n={n}"));

        let (mut d, mut s) = (base.clone(), base);
        simd::axpy_wx(-0.7, &x32, &mut d);
        simd::axpy_wx_scalar(-0.7, &x32, &mut s);
        assert_bits_eq(&d, &s, &format!("axpy_wx n={n}"));
    }
}

#[test]
fn kernels_propagate_nan_and_inf_identically() {
    // 0 × ∞ → NaN must come out of the SIMD lanes exactly as it comes out
    // of the scalar expression — same positions, same payload bits
    for n in [3usize, 8, 11] {
        let mut x = randv(n, 900 + n as u64);
        x[1] = f64::INFINITY;
        if n > 8 {
            x[9] = f64::NEG_INFINITY;
        }
        let base = vec![0.0f64; n];

        let (mut d, mut s) = (base.clone(), base.clone());
        simd::axpy_f64(0.0, &x, &mut d);
        simd::axpy_f64_scalar(0.0, &x, &mut s);
        assert!(d[1].is_nan(), "axpy dropped 0*inf at n={n}");
        assert_bits_eq(&d, &s, &format!("axpy nan n={n}"));

        // gram quad with an inf row and a zero coefficient
        let rows: Vec<Vec<f64>> = (0..4)
            .map(|r| {
                let mut v = randv(n, (950 + n + r) as u64);
                if r == 2 {
                    v[0] = f64::INFINITY;
                }
                v
            })
            .collect();
        let x4 = [1.0, 0.5, 0.0, -1.0]; // x[2] = 0 hits the inf row
        let (mut d, mut s) = (base.clone(), base);
        simd::gram4_f64(x4, [&rows[0], &rows[1], &rows[2], &rows[3]], &mut d, FmaMode::Exact);
        simd::gram4_f64_scalar(x4, [&rows[0], &rows[1], &rows[2], &rows[3]], &mut s);
        assert!(d[0].is_nan(), "gram4 dropped 0*inf at n={n}");
        assert_bits_eq(&d, &s, &format!("gram4 nan n={n}"));
    }

    // widen GEMM: f32 inf through the conversion lanes
    let a = MatrixF32::from_vec(1, 2, vec![0.0, 1.0]);
    let b = MatrixF32::from_vec(2, 1, vec![f32::INFINITY, 2.0]);
    let c = a.matmul_widen(&b, ParallelPolicy::sequential());
    assert!(c[(0, 0)].is_nan(), "widen GEMM dispatch dropped 0*inf");
}

// ---------------------------------------------------------------------------
// matrix-level: the dispatched substrate against scalar oracles and across
// worker counts, both precisions
// ---------------------------------------------------------------------------

#[test]
fn matmul_bit_identical_to_naive_across_remainder_sweep() {
    // n sweeps a full 8-lane remainder cycle around the NC tile edge;
    // m = 9 exercises one 4-row quad + 1 tail row, k spans two k-tiles
    for n in 57..=72usize {
        let a = random_matrix(9, 69, n as u64);
        let b = random_matrix(69, n, 1000 + n as u64);
        let got = a.matmul(&b);
        let want = matmul_naive(&a, &b);
        assert_eq!(got, want, "matmul 9x69x{n} != naive ijk");
    }
}

#[test]
fn matmul_degenerate_and_tall_skinny_shapes() {
    let p = ParallelPolicy::with_workers(4);
    // 0×n
    let a = Matrix::zeros(0, 5);
    let b = random_matrix(5, 3, 1);
    assert_eq!(a.matmul(&b).rows, 0);
    assert_eq!(a.matmul_with(&b, p), a.matmul(&b));
    // n×0
    let a = random_matrix(4, 6, 2);
    let b = Matrix::zeros(6, 0);
    assert_eq!(a.matmul(&b).cols, 0);
    // 1×1
    let a = Matrix::from_vec(1, 1, vec![3.0]);
    let b = Matrix::from_vec(1, 1, vec![-0.5]);
    assert_eq!(a.matmul(&b)[(0, 0)], -1.5);
    // tall-skinny (the ELM H shape): SIMD GEMM == naive ijk
    let a = random_matrix(513, 7, 3);
    let b = random_matrix(7, 5, 4);
    assert_eq!(a.matmul(&b), matmul_naive(&a, &b));
    // f32 wire twins
    let a32 = random_f32(513, 7, 5);
    let b32 = random_f32(7, 5, 6);
    assert_eq!(
        a32.matmul_widen(&b32, ParallelPolicy::sequential()),
        a32.to_f64().matmul(&b32.to_f64()),
        "widen GEMM != widened f64 GEMM on tall-skinny"
    );
    let z32 = MatrixF32::zeros(0, 7);
    assert_eq!(z32.matmul_widen(&b32, p).rows, 0);
}

#[test]
fn dispatched_kernels_worker_invariant_both_precisions() {
    // spans several MM_ROW_TILE tiles and a j remainder; 1/2/4/8 workers
    let a = random_matrix(300, 70, 10);
    let b = random_matrix(70, 66, 11);
    let seq = a.matmul(&b);
    let a32 = MatrixF32::from_matrix(&a);
    let b32 = MatrixF32::from_matrix(&b);
    let seq32 = a32.matmul_widen(&b32, ParallelPolicy::sequential());
    let gseq = a.gram_with(ParallelPolicy::sequential());
    let gseq32 = a32.gram_widen(ParallelPolicy::sequential());
    for workers in [1usize, 2, 4, 8] {
        let p = ParallelPolicy::with_workers(workers);
        assert_eq!(a.matmul_with(&b, p), seq, "matmul workers={workers}");
        assert_eq!(a32.matmul_widen(&b32, p), seq32, "matmul_widen workers={workers}");
        assert_eq!(a.gram_with(p), gseq, "gram workers={workers}");
        assert_eq!(a32.gram_widen(p), gseq32, "gram_widen workers={workers}");
    }
}

#[test]
fn t_matvec_dispatch_matches_scalar_fold() {
    for rows in [1usize, 4, 37] {
        let a = random_matrix(rows, 13, 20 + rows as u64);
        let v = randv(rows, 30 + rows as u64);
        // scalar oracle: the pre-SIMD row-major fold
        let mut want = vec![0.0f64; a.cols];
        for i in 0..rows {
            simd::axpy_f64_scalar(v[i], a.row(i), &mut want);
        }
        assert_bits_eq(&a.t_matvec(&v), &want, &format!("t_matvec rows={rows}"));

        let a32 = MatrixF32::from_matrix(&a);
        let mut want32 = vec![0.0f64; a.cols];
        for i in 0..rows {
            simd::axpy_wx_scalar(v[i], a32.row(i), &mut want32);
        }
        assert_bits_eq(&a32.t_matvec_widen(&v), &want32, &format!("t_matvec_widen rows={rows}"));
    }
}

// ---------------------------------------------------------------------------
// the FmaMode::Relaxed envelope
// ---------------------------------------------------------------------------

#[test]
fn fma_relaxed_within_envelope_and_worker_invariant() {
    let (m, k, n) = (130usize, 77usize, 66usize);
    let a = random_matrix(m, k, 40);
    let b = random_matrix(k, n, 41);
    let exact = a.matmul_with(&b, ParallelPolicy::sequential());
    let relaxed_seq =
        a.matmul_with(&b, ParallelPolicy::sequential().with_fma(FmaMode::Relaxed));

    // worker invariance holds in Relaxed mode too (fixed schedule)
    for workers in [2usize, 4, 8] {
        let p = ParallelPolicy::with_workers(workers).with_fma(FmaMode::Relaxed);
        assert_eq!(a.matmul_with(&b, p), relaxed_seq, "relaxed workers={workers}");
    }

    if !simd::fma_available() {
        // no FMA hardware (or scalar path forced): Relaxed must be a no-op
        assert_eq!(relaxed_seq, exact, "Relaxed changed bits without FMA hardware");
        return;
    }
    // documented envelope: |Δ[i,j]| ≤ k · 2⁻⁵³ · (|A|·|B|)[i,j]
    let abs_a = Matrix::from_vec(m, k, a.data().iter().map(|v| v.abs()).collect());
    let abs_b = Matrix::from_vec(k, n, b.data().iter().map(|v| v.abs()).collect());
    let envelope = matmul_naive(&abs_a, &abs_b);
    let scale = k as f64 * (2.0f64).powi(-53);
    for i in 0..m {
        for j in 0..n {
            let delta = (relaxed_seq[(i, j)] - exact[(i, j)]).abs();
            let bound = scale * envelope[(i, j)];
            assert!(
                delta <= bound,
                "({i},{j}): |Δ|={delta:e} exceeds envelope {bound:e}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// miri_ subset: the undefined-behaviour audit tier. CI runs exactly these
// under `cargo miri test --test simd_props miri_` (interpreted, so shapes
// stay tiny — one 8-lane boundary crossing each). They re-walk every
// raw-pointer path in `linalg::simd` plus the PackedPanels matmul route;
// the full-size bit-identity sweeps above stay out of the interpreter.
// ---------------------------------------------------------------------------

#[test]
fn miri_axpy_family_pointer_paths() {
    for n in [0usize, 1, 7, 9] {
        let x = randv(n, 600 + n as u64);
        let x32 = randv32(n, 700 + n as u64);
        let base = randv(n, 800 + n as u64);

        let (mut d, mut s) = (base.clone(), base.clone());
        simd::axpy_f64(-0.7, &x, &mut d);
        simd::axpy_f64_scalar(-0.7, &x, &mut s);
        assert_bits_eq(&d, &s, &format!("miri axpy_f64 n={n}"));

        let (mut d, mut s) = (base.clone(), base.clone());
        simd::axpy_sub_f64(-0.7, &x, &mut d);
        simd::axpy_sub_f64_scalar(-0.7, &x, &mut s);
        assert_bits_eq(&d, &s, &format!("miri axpy_sub_f64 n={n}"));

        let (mut d, mut s) = (base.clone(), base.clone());
        simd::axpy_widen(-0.7, &x32, &mut d);
        simd::axpy_widen_scalar(-0.7, &x32, &mut s);
        assert_bits_eq(&d, &s, &format!("miri axpy_widen n={n}"));

        let (mut d, mut s) = (base.clone(), base);
        simd::axpy_wx(-0.7, &x32, &mut d);
        simd::axpy_wx_scalar(-0.7, &x32, &mut s);
        assert_bits_eq(&d, &s, &format!("miri axpy_wx n={n}"));
    }
}

#[test]
fn miri_gemm_tile_and_row_pointer_paths() {
    // jb = 9 crosses one 8-lane boundary; kb = 3 keeps the panel walk
    // short; ldo > jb exercises the strided output-slab pointers
    let (jb, kb) = (9usize, 3usize);
    let ldo = jb + 3;
    let a: Vec<Vec<f64>> = (0..4).map(|r| randv(kb, 60 + r as u64)).collect();
    let a32: Vec<Vec<f32>> = (0..4).map(|r| randv32(kb, 80 + r as u64)).collect();
    let panel = randv(kb * jb, 61);
    let panel32 = randv32(kb * jb, 81);
    let base = randv(3 * ldo + jb, 62);

    let (mut d, mut s) = (base.clone(), base.clone());
    simd::gemm_tile_f64([&a[0], &a[1], &a[2], &a[3]], &panel, jb, &mut d, ldo, FmaMode::Exact);
    simd::gemm_tile_f64_scalar([&a[0], &a[1], &a[2], &a[3]], &panel, jb, &mut s, ldo);
    assert_bits_eq(&d, &s, "miri gemm_tile_f64");

    let (mut d, mut s) = (base.clone(), base.clone());
    simd::gemm_tile_widen(
        [&a32[0], &a32[1], &a32[2], &a32[3]],
        &panel32,
        jb,
        &mut d,
        ldo,
        FmaMode::Exact,
    );
    simd::gemm_tile_widen_scalar([&a32[0], &a32[1], &a32[2], &a32[3]], &panel32, jb, &mut s, ldo);
    assert_bits_eq(&d, &s, "miri gemm_tile_widen");

    let (mut d, mut s) = (base[..jb].to_vec(), base[..jb].to_vec());
    simd::gemm_row_f64(&a[0], &panel, jb, &mut d, FmaMode::Exact);
    simd::gemm_row_f64_scalar(&a[0], &panel, jb, &mut s);
    assert_bits_eq(&d, &s, "miri gemm_row_f64");

    let (mut d, mut s) = (base[..jb].to_vec(), base[..jb].to_vec());
    simd::gemm_row_widen(&a32[0], &panel32, jb, &mut d, FmaMode::Exact);
    simd::gemm_row_widen_scalar(&a32[0], &panel32, jb, &mut s);
    assert_bits_eq(&d, &s, "miri gemm_row_widen");
}

#[test]
fn miri_gram4_pointer_paths() {
    let n = 9usize; // one 8-lane pass + a 1-lane tail
    let rows: Vec<Vec<f64>> = (0..4).map(|r| randv(n, 90 + r as u64)).collect();
    let rows32: Vec<Vec<f32>> = (0..4).map(|r| randv32(n, 95 + r as u64)).collect();
    let x = [1.5, -0.25, 0.125, 3.0];
    let x32 = [1.5f32, -0.25, 0.125, 3.0];
    let base = randv(n, 99);

    let (mut d, mut s) = (base.clone(), base.clone());
    simd::gram4_f64(x, [&rows[0], &rows[1], &rows[2], &rows[3]], &mut d, FmaMode::Exact);
    simd::gram4_f64_scalar(x, [&rows[0], &rows[1], &rows[2], &rows[3]], &mut s);
    assert_bits_eq(&d, &s, "miri gram4_f64");

    let (mut d, mut s) = (base.clone(), base);
    simd::gram4_widen(
        x32,
        [&rows32[0], &rows32[1], &rows32[2], &rows32[3]],
        &mut d,
        FmaMode::Exact,
    );
    simd::gram4_widen_scalar(x32, [&rows32[0], &rows32[1], &rows32[2], &rows32[3]], &mut s);
    assert_bits_eq(&d, &s, "miri gram4_widen");
}

#[test]
fn miri_packed_panels_matmul() {
    // small enough to interpret, shaped to hit the packed-panel route:
    // one 4-row quad + 1 tail row, a j remainder, and a short k walk
    let a = random_matrix(5, 6, 120);
    let b = random_matrix(6, 9, 121);
    assert_eq!(a.matmul(&b), matmul_naive(&a, &b), "miri packed matmul");
    let a32 = random_f32(5, 6, 122);
    let b32 = random_f32(6, 9, 123);
    assert_eq!(
        a32.matmul_widen(&b32, ParallelPolicy::sequential()),
        a32.to_f64().matmul(&b32.to_f64()),
        "miri packed widen matmul"
    );
}

#[test]
fn fma_relaxed_gram_worker_invariant_and_bounded() {
    let a = random_matrix(1060, 9, 50); // > 2 GRAM_ROW_CHUNKs
    let exact = a.gram_with(ParallelPolicy::sequential());
    let relaxed = a.gram_with(ParallelPolicy::sequential().with_fma(FmaMode::Relaxed));
    for workers in [2usize, 4, 8] {
        let p = ParallelPolicy::with_workers(workers).with_fma(FmaMode::Relaxed);
        assert_eq!(a.gram_with(p), relaxed, "relaxed gram workers={workers}");
    }
    if !simd::fma_available() {
        assert_eq!(relaxed, exact, "Relaxed gram changed bits without FMA hardware");
        return;
    }
    // crude but sufficient: relative drift bounded by rows · 2⁻⁵³ scale
    let worst = relaxed.max_abs_diff(&exact);
    let scale = exact.frobenius().max(1.0);
    assert!(
        worst <= a.rows as f64 * (2.0f64).powi(-50) * scale,
        "relaxed gram drift {worst:e} out of envelope"
    );
}
